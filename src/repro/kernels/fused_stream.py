"""Pallas TPU kernels: fused LC-RWMD phase-1 → phase-2 (and fused top-k).

The seed pipeline materializes the full Phase-1 output ``Z (v, B)`` in HBM
between the two phases — O(v·B) write + O(n·h·B) gather re-read traffic that
the paper's bandwidth argument says we should never pay.  This kernel folds
the ELL accumulation INTO the phase-1 ``pallas_call``: each vocab subtile's
Z rows are produced in a VMEM scratch cache and consumed by the one-hot MXU
SpMM in the same grid sweep, so Z never exists in HBM at all.  The streaming
driver (ops.lc_rwmd_fused) scans the vocabulary in ``vocab_chunk``-sized
chunks and accumulates the running ``D (n, B)``; peak intermediate is the
(vocab_chunk, B) VMEM cache (see EXPERIMENTS.md §Perf for the traffic model
and VMEM budget).

Grid: ``(n // block_n, cv // block_v)`` — doc tiles outer, vocab subtiles
inner, so the (block_n, B) output block accumulates across consecutive
subtile steps (the Pallas-safe revisit pattern).  The Z cache is computed
once, during the first doc tile's sweep (``i == 0``), and re-read from VMEM
by every later doc tile.

Blocks (all VMEM):
  emb    (block_v, m)   index (i, j) -> (j, 0)    vocab subtile
  t      (B, h, m)      index (i, j) -> 0         query word embeddings
  valid  (B, h)         index (i, j) -> 0         f32 0/1 query mask
  ids    (block_n, h1)  index (i, j) -> (i, 0)    CHUNK-RELATIVE ELL ids
  w      (block_n, h1)  index (i, j) -> (i, 0)    weights, 0 outside chunk
  out D  (block_n, B)   index (i, j) -> (i, 0)    revisited over j
  scratch z_cache (cv, B) — persists across the whole grid.

Alignment contract (enforced by ops.lc_rwmd_fused): m and B padded to lane
width where required, cv % block_v == 0, n % block_n == 0.  ``ids`` must be
pre-shifted into [0, cv) with out-of-chunk slots clipped and their weights
zeroed — the chunk offset never enters the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INF = 3.4e38  # large finite sentinel (Python float: kernels cannot capture consts)


def _fused_kernel(
    emb_ref, t_ref, valid_ref, ids_ref, w_ref, out_ref, z_cache,
    *, block_v: int, bf16_matmul: bool,
):
    i = pl.program_id(0)  # doc tile
    j = pl.program_id(1)  # vocab subtile
    n_b, h = valid_ref.shape

    @pl.when(i == 0)
    def _compute_z_subtile():
        e = emb_ref[...]                           # (bv, m)
        t = t_ref[...].reshape(n_b * h, -1)        # (B·h, m)
        valid = valid_ref[...].reshape(-1)         # (B·h,)
        e2 = jnp.sum(e * e, axis=-1, keepdims=True)
        t2 = jnp.sum(t * t, axis=-1, keepdims=True).T
        if bf16_matmul:
            et = jax.lax.dot_general(
                e.astype(jnp.bfloat16), t.astype(jnp.bfloat16),
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
            )
        else:
            et = jax.lax.dot_general(
                e, t, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        sq = jnp.maximum(e2 + t2 - 2.0 * et, 0.0)  # (bv, B·h)
        sq = jnp.where(valid[None, :] > 0, sq, _INF)
        zmin = jnp.min(sq.reshape(block_v, n_b, h), axis=2)
        z = jnp.sqrt(jnp.maximum(zmin, 0.0))       # (bv, B)
        pad_b = z_cache.shape[1] - n_b
        z = jnp.concatenate(
            [z, jnp.zeros((block_v, pad_b), jnp.float32)], axis=1)
        z_cache[pl.ds(j * block_v, block_v), :] = z

    # One-hot ELL accumulation against the (just-)cached Z subtile (MXU).
    ids = ids_ref[...]                             # (bn, h1) in [0, cv)
    w = w_ref[...]
    bn, h1 = ids.shape
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, (bn, h1, block_v), 2)
    a = jnp.sum((ids[:, :, None] == cols).astype(jnp.float32) * w[:, :, None],
                axis=1)                            # (bn, bv)
    z_sub = z_cache[pl.ds(j * block_v, block_v), :]
    contrib = jax.lax.dot_general(
        a, z_sub, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += contrib


def fused_lc_rwmd_chunk_pallas(
    emb_chunk: jax.Array,   # (cv, m) f32 vocab-chunk embedding rows
    t: jax.Array,           # (B, h, m) f32 query word embeddings
    valid: jax.Array,       # (B, h) f32 0/1
    ids_rel: jax.Array,     # (n, h1) int32, chunk-relative, clipped to [0, cv)
    w_masked: jax.Array,    # (n, h1) f32, 0 at padding AND out-of-chunk slots
    *,
    block_v: int = 256,
    block_n: int = 8,
    bf16_matmul: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Partial D (n, B_pad) contribution of one vocab chunk, fully fused.

    Returns the chunk's Σ_p w[i,p]·Z_chunk[ids[i,p], j] with Z_chunk living
    only in VMEM.  Callers accumulate chunk contributions and slice the lane
    padding off the B axis.
    """
    cv, m = emb_chunk.shape
    n_b, h, _ = t.shape
    n, h1 = ids_rel.shape
    if cv % block_v != 0 or n % block_n != 0:
        raise ValueError(
            f"cv={cv} / n={n} not multiples of block_v={block_v} / block_n={block_n}")
    b_pad = max(128, n_b)  # lane-width accumulator/cache
    grid = (n // block_n, cv // block_v)

    return pl.pallas_call(
        functools.partial(_fused_kernel, block_v=block_v, bf16_matmul=bf16_matmul),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, m), lambda i, j: (j, 0)),
            pl.BlockSpec((n_b, h, m), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((n_b, h), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, h1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, h1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, b_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((cv, b_pad), jnp.float32)],
        interpret=interpret,
    )(emb_chunk, t, valid, ids_rel, w_masked)


# ---------------------------------------------------------------------------
# Fused streaming top-k: phase-1 → phase-2 → per-query k-smallest carry
# ---------------------------------------------------------------------------
def _insert_candidates(cv, ci, d_blk, base_gid, n_real, block_n):
    """Insert block_n per-query candidates into a sorted (k_sub, b) carry.

    ``cv``/``ci`` hold per-query candidate lists down the SUBLANE axis,
    ascending by the shared lexicographic key (value, global id) — the same
    order every jnp top-k path in core/topk.py produces.  Each candidate
    row r of ``d_blk`` (block_n, b) is a lane vector; its insertion rank per
    query is a sublane-count, and the insert itself is a one-sublane shift —
    no in-kernel sort needed (Mosaic has none), O(block_n · k_sub) VPU ops.
    """
    k_sub, b = cv.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (k_sub, b), 0)
    for r in range(block_n):
        gid = base_gid + r
        v_r = d_blk[r:r + 1, :]                       # (1, b)
        v_r = jnp.where(gid < n_real, v_r, _INF)      # padded doc rows drop
        # Slots strictly before the insert point: smaller value, or equal
        # value with smaller global id (candidate gids are unique).
        before = (cv < v_r) | ((cv == v_r) & (ci < gid))
        rank = jnp.sum(before.astype(jnp.int32), axis=0, keepdims=True)
        down_v = jnp.concatenate(
            [jnp.full((1, b), _INF, jnp.float32), cv[:-1, :]], axis=0)
        down_i = jnp.concatenate(
            [jnp.full((1, b), -1, jnp.int32), ci[:-1, :]], axis=0)
        cv = jnp.where(pos < rank, cv, jnp.where(pos == rank, v_r, down_v))
        ci = jnp.where(pos < rank, ci, jnp.where(pos == rank, gid, down_i))
        # rank == k_sub ⇒ no slot matches ⇒ the candidate is dropped (it is
        # no smaller than everything already kept) — exactly top-k semantics.
    return cv, ci


def _fused_topk_kernel(
    emb_ref, t_ref, valid_ref, ids_ref, w_ref, vals_ref, idx_ref,
    z_cache, d_acc, *, block_v: int, block_n: int, n_real: int,
    bf16_matmul: bool,
):
    i = pl.program_id(0)   # doc tile
    j = pl.program_id(1)   # vocab subtile
    nj = pl.num_programs(1)
    n_b, h = valid_ref.shape
    b_pad = z_cache.shape[1]

    @pl.when((i == 0) & (j == 0))
    def _init_carry():
        vals_ref[...] = jnp.full(vals_ref.shape, _INF, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, -1, jnp.int32)

    @pl.when(i == 0)
    def _compute_z_subtile():
        e = emb_ref[...]                           # (bv, m)
        t = t_ref[...].reshape(n_b * h, -1)        # (B·h, m)
        valid = valid_ref[...].reshape(-1)         # (B·h,)
        e2 = jnp.sum(e * e, axis=-1, keepdims=True)
        t2 = jnp.sum(t * t, axis=-1, keepdims=True).T
        if bf16_matmul:
            et = jax.lax.dot_general(
                e.astype(jnp.bfloat16), t.astype(jnp.bfloat16),
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
            )
        else:
            et = jax.lax.dot_general(
                e, t, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        sq = jnp.maximum(e2 + t2 - 2.0 * et, 0.0)  # (bv, B·h)
        sq = jnp.where(valid[None, :] > 0, sq, _INF)
        zmin = jnp.min(sq.reshape(block_v, n_b, h), axis=2)
        z = jnp.sqrt(jnp.maximum(zmin, 0.0))       # (bv, B)
        pad_b = b_pad - n_b
        z = jnp.concatenate(
            [z, jnp.zeros((block_v, pad_b), jnp.float32)], axis=1)
        z_cache[pl.ds(j * block_v, block_v), :] = z

    # One-hot ELL accumulation against the cached Z subtile (MXU).  Ids are
    # ABSOLUTE vocab rows here (the kernel sees the whole restricted vocab),
    # so the subtile selection falls out of the iota comparison directly.
    ids = ids_ref[...]                             # (bn, h1) in [0, v)
    w = w_ref[...]
    bn, h1 = ids.shape
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, (bn, h1, block_v), 2)
    a = jnp.sum((ids[:, :, None] == cols).astype(jnp.float32) * w[:, :, None],
                axis=1)                            # (bn, bv)
    z_sub = z_cache[pl.ds(j * block_v, block_v), :]
    contrib = jax.lax.dot_general(
        a, z_sub, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        d_acc[...] = contrib

    @pl.when(j > 0)
    def _acc():
        d_acc[...] += contrib

    @pl.when(j == nj - 1)
    def _merge_rows():
        # The doc tile's distances are complete — fold them into the carry
        # and let d_acc be overwritten by the next tile.  The (n, B) matrix
        # never exists: per-tile distances live only in this VMEM scratch.
        cv, ci = _insert_candidates(
            vals_ref[...], idx_ref[...], d_acc[...], i * block_n, n_real,
            block_n)
        vals_ref[...] = cv
        idx_ref[...] = ci


def fused_lc_rwmd_topk_pallas(
    emb: jax.Array,         # (v_pad, m) f32 restricted-vocab embedding rows
    t: jax.Array,           # (B, h, m) f32 query word embeddings
    valid: jax.Array,       # (B, h) f32 0/1
    ids: jax.Array,         # (n_pad, h1) int32 ABSOLUTE resident ELL ids
    w: jax.Array,           # (n_pad, h1) f32, 0 at padding slots/rows
    *,
    k: int,
    n_real: int,
    block_v: int = 256,
    block_n: int = 8,
    bf16_matmul: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Streaming one-sided LC-RWMD top-k: the (n, B) matrix never leaves VMEM.

    Same grid sweep as :func:`fused_lc_rwmd_chunk_pallas` (doc tiles outer,
    vocab subtiles inner; Z cached in VMEM on the first doc tile's pass), but
    the per-tile distance block is accumulated in a (block_n, B) VMEM scratch
    and, once its vocab sweep completes, merged into a sorted per-query
    (k, B) carry held in the revisited output blocks.  HBM output is the
    O(k·B) carry — phase-2 distances are never written back at all.

    Returns ``(vals (k_sub, b_pad), gids (k_sub, b_pad))``; callers slice
    ``[:k, :B]`` and transpose.  Rows ≥ ``n_real`` (doc-axis padding) are
    masked inside the accumulator.  VMEM budget: the full (v_pad, b_pad) Z
    cache — callers bound v_pad (the engine's restricted vocab qualifies) or
    fall back to the jnp streaming path.
    """
    v_pad, m = emb.shape
    n_b, h, _ = t.shape
    n_pad, h1 = ids.shape
    if v_pad % block_v != 0 or n_pad % block_n != 0:
        raise ValueError(
            f"v={v_pad} / n={n_pad} not multiples of block_v={block_v} / "
            f"block_n={block_n}")
    b_pad = max(128, n_b)       # lane-width Z cache / distance blocks
    k_sub = -(-max(k, 1) // 8) * 8  # sublane-aligned carry height
    grid = (n_pad // block_n, v_pad // block_v)

    return pl.pallas_call(
        functools.partial(
            _fused_topk_kernel, block_v=block_v, block_n=block_n,
            n_real=n_real, bf16_matmul=bf16_matmul),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, m), lambda i, j: (j, 0)),
            pl.BlockSpec((n_b, h, m), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((n_b, h), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, h1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, h1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k_sub, b_pad), lambda i, j: (0, 0)),
            pl.BlockSpec((k_sub, b_pad), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_sub, b_pad), jnp.float32),
            jax.ShapeDtypeStruct((k_sub, b_pad), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((v_pad, b_pad), jnp.float32),
            pltpu.VMEM((block_n, b_pad), jnp.float32),
        ],
        interpret=interpret,
    )(emb, t, valid, ids, w)
