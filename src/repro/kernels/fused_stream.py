"""Pallas TPU kernel: fused LC-RWMD phase-1 → phase-2 over one vocab chunk.

The seed pipeline materializes the full Phase-1 output ``Z (v, B)`` in HBM
between the two phases — O(v·B) write + O(n·h·B) gather re-read traffic that
the paper's bandwidth argument says we should never pay.  This kernel folds
the ELL accumulation INTO the phase-1 ``pallas_call``: each vocab subtile's
Z rows are produced in a VMEM scratch cache and consumed by the one-hot MXU
SpMM in the same grid sweep, so Z never exists in HBM at all.  The streaming
driver (ops.lc_rwmd_fused) scans the vocabulary in ``vocab_chunk``-sized
chunks and accumulates the running ``D (n, B)``; peak intermediate is the
(vocab_chunk, B) VMEM cache (see EXPERIMENTS.md §Perf for the traffic model
and VMEM budget).

Grid: ``(n // block_n, cv // block_v)`` — doc tiles outer, vocab subtiles
inner, so the (block_n, B) output block accumulates across consecutive
subtile steps (the Pallas-safe revisit pattern).  The Z cache is computed
once, during the first doc tile's sweep (``i == 0``), and re-read from VMEM
by every later doc tile.

Blocks (all VMEM):
  emb    (block_v, m)   index (i, j) -> (j, 0)    vocab subtile
  t      (B, h, m)      index (i, j) -> 0         query word embeddings
  valid  (B, h)         index (i, j) -> 0         f32 0/1 query mask
  ids    (block_n, h1)  index (i, j) -> (i, 0)    CHUNK-RELATIVE ELL ids
  w      (block_n, h1)  index (i, j) -> (i, 0)    weights, 0 outside chunk
  out D  (block_n, B)   index (i, j) -> (i, 0)    revisited over j
  scratch z_cache (cv, B) — persists across the whole grid.

Alignment contract (enforced by ops.lc_rwmd_fused): m and B padded to lane
width where required, cv % block_v == 0, n % block_n == 0.  ``ids`` must be
pre-shifted into [0, cv) with out-of-chunk slots clipped and their weights
zeroed — the chunk offset never enters the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INF = 3.4e38  # large finite sentinel (Python float: kernels cannot capture consts)


def _fused_kernel(
    emb_ref, t_ref, valid_ref, ids_ref, w_ref, out_ref, z_cache,
    *, block_v: int, bf16_matmul: bool,
):
    i = pl.program_id(0)  # doc tile
    j = pl.program_id(1)  # vocab subtile
    n_b, h = valid_ref.shape

    @pl.when(i == 0)
    def _compute_z_subtile():
        e = emb_ref[...]                           # (bv, m)
        t = t_ref[...].reshape(n_b * h, -1)        # (B·h, m)
        valid = valid_ref[...].reshape(-1)         # (B·h,)
        e2 = jnp.sum(e * e, axis=-1, keepdims=True)
        t2 = jnp.sum(t * t, axis=-1, keepdims=True).T
        if bf16_matmul:
            et = jax.lax.dot_general(
                e.astype(jnp.bfloat16), t.astype(jnp.bfloat16),
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
            )
        else:
            et = jax.lax.dot_general(
                e, t, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        sq = jnp.maximum(e2 + t2 - 2.0 * et, 0.0)  # (bv, B·h)
        sq = jnp.where(valid[None, :] > 0, sq, _INF)
        zmin = jnp.min(sq.reshape(block_v, n_b, h), axis=2)
        z = jnp.sqrt(jnp.maximum(zmin, 0.0))       # (bv, B)
        pad_b = z_cache.shape[1] - n_b
        z = jnp.concatenate(
            [z, jnp.zeros((block_v, pad_b), jnp.float32)], axis=1)
        z_cache[pl.ds(j * block_v, block_v), :] = z

    # One-hot ELL accumulation against the (just-)cached Z subtile (MXU).
    ids = ids_ref[...]                             # (bn, h1) in [0, cv)
    w = w_ref[...]
    bn, h1 = ids.shape
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, (bn, h1, block_v), 2)
    a = jnp.sum((ids[:, :, None] == cols).astype(jnp.float32) * w[:, :, None],
                axis=1)                            # (bn, bv)
    z_sub = z_cache[pl.ds(j * block_v, block_v), :]
    contrib = jax.lax.dot_general(
        a, z_sub, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += contrib


def fused_lc_rwmd_chunk_pallas(
    emb_chunk: jax.Array,   # (cv, m) f32 vocab-chunk embedding rows
    t: jax.Array,           # (B, h, m) f32 query word embeddings
    valid: jax.Array,       # (B, h) f32 0/1
    ids_rel: jax.Array,     # (n, h1) int32, chunk-relative, clipped to [0, cv)
    w_masked: jax.Array,    # (n, h1) f32, 0 at padding AND out-of-chunk slots
    *,
    block_v: int = 256,
    block_n: int = 8,
    bf16_matmul: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Partial D (n, B_pad) contribution of one vocab chunk, fully fused.

    Returns the chunk's Σ_p w[i,p]·Z_chunk[ids[i,p], j] with Z_chunk living
    only in VMEM.  Callers accumulate chunk contributions and slice the lane
    padding off the B axis.
    """
    cv, m = emb_chunk.shape
    n_b, h, _ = t.shape
    n, h1 = ids_rel.shape
    if cv % block_v != 0 or n % block_n != 0:
        raise ValueError(
            f"cv={cv} / n={n} not multiples of block_v={block_v} / block_n={block_n}")
    b_pad = max(128, n_b)  # lane-width accumulator/cache
    grid = (n // block_n, cv // block_v)

    return pl.pallas_call(
        functools.partial(_fused_kernel, block_v=block_v, bf16_matmul=bf16_matmul),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, m), lambda i, j: (j, 0)),
            pl.BlockSpec((n_b, h, m), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((n_b, h), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, h1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, h1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, b_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((cv, b_pad), jnp.float32)],
        interpret=interpret,
    )(emb_chunk, t, valid, ids_rel, w_masked)
