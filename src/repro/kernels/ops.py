"""jit'd public wrappers around the Pallas kernels.

Responsibilities: hardware-alignment padding (m, h → multiples of 128;
v, n → multiples of the v/n block), dtype policy, interpret-mode fallback on
CPU (the kernels target TPU; ``interpret=True`` executes the kernel body in
Python for validation, per the repo's CPU-container contract), and
un-padding of results.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_stream as _fs
from repro.kernels import lc_rwmd_phase1 as _p1
from repro.kernels import rwmd_pairwise as _rw
from repro.kernels import segment_spmm as _seg
from repro.kernels import sinkhorn_wmd as _sk
from repro.kernels import spmm_ell as _sp

Array = jax.Array

_INF = 3.4e38


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: Array, mult: int, axis: int, value=0) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _phase1_padded(
    emb_f: Array,    # (v_pad, m_pad) f32, already block/lane aligned
    t: Array,        # (B, h, m_pad) f32 pre-gathered query word embeddings
    valid: Array,    # (B, h) f32 0/1
    v_out: int,
    *,
    block_v: int,
    block_h: int,
    bf16_matmul: bool,
    interpret: bool,
) -> Array:
    t = _pad_to(t, block_h, axis=1)
    valid = _pad_to(valid, block_h, axis=1)
    z_sq = _p1.lc_rwmd_phase1_pallas(
        emb_f, t, valid,
        block_v=block_v, block_h=min(block_h, t.shape[1]),
        bf16_matmul=bf16_matmul, interpret=interpret,
    )
    return jnp.sqrt(jnp.maximum(z_sq[:v_out], 0.0))


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_h", "bf16_matmul", "interpret")
)
def lc_rwmd_phase1(
    emb: Array,      # (v, m) float
    q_ids: Array,    # (B, h) int32
    q_w: Array,      # (B, h) float (0 = padding)
    *,
    block_v: int = 512,
    block_h: int = 128,
    bf16_matmul: bool = False,
    interpret: bool | None = None,
) -> Array:
    """Z (v, B) f32 — min distance from every vocab word to each query doc."""
    if interpret is None:
        interpret = _on_cpu()
    v, m = emb.shape
    b, h = q_ids.shape

    emb_f = _pad_to(_pad_to(emb.astype(jnp.float32), 128, axis=1), block_v, axis=0)
    t = emb_f[q_ids.reshape(-1)].reshape(b, h, emb_f.shape[1])
    valid = (q_w > 0).astype(jnp.float32)
    return _phase1_padded(
        emb_f, t, valid, v, block_v=block_v, block_h=block_h,
        bf16_matmul=bf16_matmul, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_h", "bf16_matmul", "interpret")
)
def lc_rwmd_phase1_pregathered(
    emb: Array,      # (v, m) float — the vocab axis of Z
    t: Array,        # (B, h, m) float — PRE-GATHERED query word embeddings
    valid: Array,    # (B, h) float 0/1
    *,
    block_v: int = 512,
    block_h: int = 128,
    bf16_matmul: bool = False,
    interpret: bool | None = None,
) -> Array:
    """Phase 1 with the query gather hoisted out (LCRWMDEngine shares it)."""
    if interpret is None:
        interpret = _on_cpu()
    v = emb.shape[0]
    emb_f = _pad_to(_pad_to(emb.astype(jnp.float32), 128, axis=1), block_v, axis=0)
    t = _pad_to(t.astype(jnp.float32), emb_f.shape[1], axis=2)
    return _phase1_padded(
        emb_f, t, valid.astype(jnp.float32), v, block_v=block_v,
        block_h=block_h, bf16_matmul=bf16_matmul, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_v", "mode", "interpret")
)
def spmm_ell(
    ids: Array,   # (n, h) int32
    w: Array,     # (n, h) float
    z: Array,     # (v, B) float
    *,
    block_n: int = 8,
    block_v: int = 256,
    mode: str = "blocked",
    interpret: bool | None = None,
) -> Array:
    """D (n, B) f32 = ELL-sparse(ids, w) @ z.

    ``mode``: "blocked" (grid (n/block_n, h), block_n gathered-row DMAs per
    step), "dense" (one-hot MXU formulation for small vocab), or "naive"
    (the seed one-row-per-step grid, kept as the recorded baseline).
    """
    if interpret is None:
        interpret = _on_cpu()
    n, h = ids.shape
    z_p = _pad_to(z.astype(jnp.float32), 128, axis=1)
    w_f = w.astype(jnp.float32)
    if mode == "naive":
        out = _sp.spmm_ell_naive_pallas(ids, w_f, z_p, interpret=interpret)
        return out[:n, : z.shape[1]]
    # Pad the doc axis to the tile size; padding docs carry weight 0.
    ids_p = _pad_to(ids, block_n, axis=0)
    w_p = _pad_to(w_f, block_n, axis=0)
    if mode == "blocked":
        out = _sp.spmm_ell_pallas(
            ids_p, w_p, z_p, block_n=block_n, interpret=interpret)
    elif mode == "dense":
        z_p = _pad_to(z_p, block_v, axis=0)
        out = _sp.spmm_ell_dense_pallas(
            ids_p, w_p, z_p, block_n=block_n, block_v=block_v,
            interpret=interpret)
    else:
        raise ValueError(f"unknown spmm mode {mode!r}")
    return out[:n, : z.shape[1]]


@functools.partial(
    jax.jit,
    static_argnames=("vocab_chunk", "fuse", "block_n", "block_v", "block_h",
                     "bf16_matmul", "interpret"),
)
def lc_rwmd_fused(
    emb: Array,      # (v, m) float
    q_ids: Array,    # (B, h) int32
    q_w: Array,      # (B, h) float (0 = padding)
    r_ids: Array,    # (n, h1) int32 resident ELL ids
    r_w: Array,      # (n, h1) float resident weights (0 = padding)
    *,
    vocab_chunk: int = 512,
    fuse: str = "scan",
    block_n: int = 8,
    block_v: int = 256,
    block_h: int = 128,
    bf16_matmul: bool = False,
    interpret: bool | None = None,
) -> Array:
    """Streaming phase-1→phase-2: D (n, B) f32 without a full Z (v, B).

    Scans the vocabulary in ``vocab_chunk``-sized chunks; each chunk's Z tile
    is produced, immediately consumed into the running D accumulator, and
    discarded, so the peak intermediate is (vocab_chunk, B) instead of the
    seed pipeline's (v, B).

    ``fuse``:
      "kernel" — one fused pallas_call per chunk (fused_stream.py): Z lives
                 only in a VMEM scratch cache, never in HBM.
      "scan"   — double-buffered composition of the phase-1 kernel and the
                 blocked SpMM kernel per chunk (Z bounded at (chunk, B) HBM).
      "jnp"    — pure-jnp streaming oracle (XLA:CPU reference + tests).
    """
    if interpret is None:
        interpret = _on_cpu()
    v, m = emb.shape
    b, h = q_ids.shape
    n, h1 = r_ids.shape

    # Chunk size aligned to the vocab subtile; vocab padded to chunk multiple.
    bv = min(block_v, vocab_chunk)
    vc = -(-vocab_chunk // bv) * bv
    emb_f = _pad_to(_pad_to(emb.astype(jnp.float32), 128, axis=1), vc, axis=0)
    n_chunks = emb_f.shape[0] // vc
    t = emb_f[q_ids.reshape(-1)].reshape(b, h, emb_f.shape[1])
    valid = (q_w > 0).astype(jnp.float32)

    r_ids_p = _pad_to(r_ids, block_n, axis=0)
    r_w_p = _pad_to(r_w.astype(jnp.float32), block_n, axis=0)

    emb_chunks = emb_f.reshape(n_chunks, vc, emb_f.shape[1])
    offsets = jnp.arange(n_chunks, dtype=jnp.int32) * vc

    def chunk_step(d_acc, xs):
        e_c, lo = xs
        rel = r_ids_p - lo
        inb = (rel >= 0) & (rel < vc)
        rel_c = jnp.clip(rel, 0, vc - 1).astype(jnp.int32)
        w_m = r_w_p * inb.astype(jnp.float32)
        if fuse == "kernel":
            d_c = _fs.fused_lc_rwmd_chunk_pallas(
                e_c, t, valid, rel_c, w_m,
                block_v=bv, block_n=block_n, bf16_matmul=bf16_matmul,
                interpret=interpret,
            )[:, :b]
        elif fuse == "scan":
            z = _phase1_padded(
                e_c, t, valid, vc, block_v=bv, block_h=block_h,
                bf16_matmul=bf16_matmul, interpret=interpret,
            )
            z_p = _pad_to(z, 128, axis=1)
            d_c = _sp.spmm_ell_pallas(
                rel_c, w_m, z_p, block_n=block_n, interpret=interpret,
            )[:, :b]
        elif fuse == "jnp":
            from repro.core.distances import sq_dists

            sq = sq_dists(e_c, t.reshape(b * h, -1), bf16_matmul=bf16_matmul)
            sq = jnp.where(valid.reshape(-1)[None, :] > 0, sq, _INF)
            z = jnp.sqrt(jnp.maximum(jnp.min(sq.reshape(vc, b, h), axis=2), 0.0))
            d_c = jnp.einsum("nh,nhb->nb", w_m, z[rel_c])
        else:
            raise ValueError(f"unknown fuse mode {fuse!r}")
        return d_acc + d_c, None

    d0 = jnp.zeros((r_ids_p.shape[0], b), jnp.float32)
    d, _ = jax.lax.scan(chunk_step, d0, (emb_chunks, offsets), unroll=2)
    return d[:n]


def streaming_phase2_topk(
    r_ids: Array,    # (n, h1) int32 resident ELL ids (into z's vocab axis)
    r_w: Array,      # (n, h1) float resident weights (0 = padding)
    z: Array,        # (v, B) f32 phase-1 output
    k: int,
    *,
    row_block: int = 128,
    q_gid: Array | None = None,  # (B,) global ids to self-exclude, or None
    row_valid: Array | None = None,  # (n,) bool row mask (tombstones), or None
) -> tuple[Array, Array]:
    """Phase-2 ELL SpMM streamed straight into a per-query top-k carry.

    The jnp/scan reduction behind every streaming top-k fallback: resident
    rows are scanned in ``row_block``-sized slabs, each slab's (R, B) partial
    distances folded into a :class:`~repro.core.topk.StreamingTopK` carry —
    the (n, B) matrix never materializes (peak live slab: (R, B)).  Returns
    ``(dists (B, k), indices (B, k))``, exactly equal (ties included) to
    ``lax.top_k`` over the materialized matrix.

    ``row_valid`` masks individual resident rows to +inf (the segmented
    engine's tombstones): a traced array argument, so flipping entries never
    re-compiles.  ``row_valid=None`` and an all-True mask are exactly equal.
    """
    from repro.core.topk import StreamingTopK

    n, h1 = r_ids.shape
    b = z.shape[1]
    kk = min(k, n)
    r = min(row_block, n)
    nb = -(-n // r)
    ids_b = _pad_to(r_ids, nb * r, axis=0).reshape(nb, r, h1)
    w_b = _pad_to(r_w.astype(jnp.float32), nb * r, axis=0).reshape(nb, r, h1)
    los = jnp.arange(nb, dtype=jnp.int32) * r
    if row_valid is not None:
        valid_b = _pad_to(row_valid, nb * r, axis=0).reshape(nb, r)
        xs = (ids_b, w_b, los, valid_b)
    else:
        xs = (ids_b, w_b, los, None)

    stk = StreamingTopK(kk)

    def body(carry, xs):
        ids_blk, w_blk, lo, valid_blk = xs
        zg = z[ids_blk]                              # (R, h1, B)
        d_blk = jnp.einsum("rh,rhb->rb", w_blk, zg)  # (R, B)
        row = lo + jnp.arange(r, dtype=jnp.int32)
        d_blk = jnp.where((row < n)[:, None], d_blk, jnp.inf)
        if valid_blk is not None:
            d_blk = jnp.where(valid_blk[:, None], d_blk, jnp.inf)
        if q_gid is not None:
            d_blk = jnp.where(row[:, None] == q_gid[None, :], jnp.inf, d_blk)
        return stk.update_cols(carry, d_blk, row), None

    carry, _ = jax.lax.scan(body, stk.init(b), xs)
    return carry.dists, carry.indices


@functools.partial(
    jax.jit,
    static_argnames=("k", "fuse", "row_block", "block_n", "block_v",
                     "block_h", "vocab_chunk", "bf16_matmul", "interpret"),
)
def lc_rwmd_fused_topk(
    emb: Array,      # (v, m) float
    q_ids: Array,    # (B, h) int32
    q_w: Array,      # (B, h) float (0 = padding)
    r_ids: Array,    # (n, h1) int32 resident ELL ids
    r_w: Array,      # (n, h1) float resident weights (0 = padding)
    *,
    k: int,
    fuse: str = "jnp",
    row_block: int = 128,
    block_n: int = 8,
    block_v: int = 256,
    block_h: int = 128,
    vocab_chunk: int = 512,
    bf16_matmul: bool = False,
    interpret: bool | None = None,
) -> tuple[Array, Array]:
    """Streaming one-sided LC-RWMD top-k: (B, k) dists + global doc ids.

    Candidate selection is fused into the phase-2 accumulator, so the (n, B)
    distance matrix never reaches HBM — the serve hot path's dominant
    round-trip (ROADMAP item 3).  Exactly equal (ties included) to
    ``lax.top_k`` over :func:`lc_rwmd_fused`'s output.

    Shapes: ``emb (v, m)``, ``q_ids``/``q_w (B, h)``, ``r_ids``/``r_w
    (n, h1)`` → ``(dists (B, k), doc_ids (B, k))``, distances ascending.

    JIT-STATIC kwargs (each distinct value compiles a new program): ``k``,
    ``fuse``, and every tiling knob — ``row_block`` (jnp slab rows),
    ``block_n``/``block_v``/``block_h`` (Pallas tile sizes), ``vocab_chunk``
    (phase-1 chunking), plus ``bf16_matmul``/``interpret``.  Only the array
    arguments may vary call-to-call without recompiling.

    ``fuse``:
      "kernel" — one fused pallas_call (fused_stream.fused_lc_rwmd_topk_pallas):
                 Z lives in a VMEM cache, per-tile distances in a VMEM
                 scratch, the sorted (k, B) carry in the revisited output
                 block.  HBM peak: O(k·B).  VMEM bounds v (use the engine's
                 restricted vocab).
      "jnp"    — phase-1 Z (v, B) in chunks, then the scan reduction of
                 :func:`streaming_phase2_topk`.  HBM peak: O(v·B) for Z
                 (v ≪ n at serving scale) — never O(n·B).
    """
    if interpret is None:
        interpret = _on_cpu()
    n = r_ids.shape[0]
    b = q_ids.shape[0]
    kk = min(k, n)

    if fuse == "kernel":
        bv = block_v
        emb_f = _pad_to(
            _pad_to(emb.astype(jnp.float32), 128, axis=1), bv, axis=0)
        t = emb_f[q_ids.reshape(-1)].reshape(b, q_ids.shape[1], -1)
        valid = (q_w > 0).astype(jnp.float32)
        ids_p = _pad_to(r_ids, block_n, axis=0)
        w_p = _pad_to(r_w.astype(jnp.float32), block_n, axis=0)
        vals, gids = _fs.fused_lc_rwmd_topk_pallas(
            emb_f, t, valid, ids_p, w_p, k=kk, n_real=n, block_v=bv,
            block_n=block_n, bf16_matmul=bf16_matmul, interpret=interpret)
        return vals[:kk, :b].T, gids[:kk, :b].T
    if fuse == "jnp":
        from repro.core.lc_rwmd import phase1_z

        z = phase1_z(emb, q_ids, q_w, bf16_matmul=bf16_matmul,
                     vocab_chunk=vocab_chunk)
        return streaming_phase2_topk(r_ids, r_w, z, kk, row_block=row_block)
    raise ValueError(f"unknown fuse mode {fuse!r}")


@functools.partial(
    jax.jit, static_argnames=("block_n", "bf16_matmul", "interpret")
)
def rwmd_pairwise(
    emb: Array,       # (v, m)
    r_ids: Array,     # (n, h1) resident ids
    r_w: Array,       # (n, h1)
    q_ids: Array,     # (B, h2) query ids
    q_w: Array,       # (B, h2)
    *,
    block_n: int = 8,
    bf16_matmul: bool = False,
    interpret: bool | None = None,
) -> Array:
    """Quadratic RWMD distance matrix (n, B) f32, fully fused per tile."""
    if interpret is None:
        interpret = _on_cpu()
    emb_f = _pad_to(emb.astype(jnp.float32), 128, axis=1)
    n, h1 = r_ids.shape
    b, h2 = q_ids.shape

    t1 = emb_f[r_ids.reshape(-1)].reshape(n, h1, emb_f.shape[1])
    t2 = emb_f[q_ids.reshape(-1)].reshape(b, h2, emb_f.shape[1])
    # Pad word axes to lane width so min-reductions stay aligned; padding
    # words get weight 0 (=> masked inside the kernel).
    t1 = _pad_to(t1, 128, axis=1)
    w1 = _pad_to(r_w.astype(jnp.float32), 128, axis=1)
    t2 = _pad_to(t2, 128, axis=1)
    w2 = _pad_to(q_w.astype(jnp.float32), 128, axis=1)
    # Pad doc axis to the doc-tile size.
    t1 = _pad_to(t1, block_n, axis=0)
    w1 = _pad_to(w1, block_n, axis=0)

    out = _rw.rwmd_pairwise_pallas(
        t1, w1, t2, w2,
        block_n=block_n, bf16_matmul=bf16_matmul, interpret=interpret,
    )
    return out[:n]


@functools.partial(
    jax.jit,
    static_argnames=("eps", "eps_scaling", "eps_start", "max_iters", "tol",
                     "block_p", "bf16_matmul", "interpret"),
)
def sinkhorn_wmd(
    t1: Array,    # (P, h1, m) candidate word embeddings (pre-gathered)
    w1: Array,    # (P, h1) weights (0 = padding)
    t2: Array,    # (P, h2, m) query word embeddings
    w2: Array,    # (P, h2)
    *,
    eps: float = 0.01,
    eps_scaling: int = 4,
    eps_start: float = 1.0,
    max_iters: int = 500,
    tol: float = 1e-5,
    block_p: int = 8,
    bf16_matmul: bool = False,
    interpret: bool | None = None,
) -> Array:
    """Fused batched Sinkhorn-WMD costs (P,) f32 — cost tiles built in VMEM.

    The (P, h1, h2) cost stack is never materialized in HBM: each pair
    block's tiles are produced from the gathered embeddings on the fly and
    consumed by the in-kernel ε-scaled scaling loop (per-pair convergence
    masks within the block).
    """
    if interpret is None:
        interpret = _on_cpu()
    p, h1, _ = t1.shape
    h2 = t2.shape[1]
    # Lane-align the embedding and word axes; padding words carry weight 0
    # (masked in log domain inside the kernel).  Padding PAIRS (P axis) are
    # all-zero-weight problems that converge on their first iteration.
    t1 = _pad_to(t1.astype(jnp.float32), 128, axis=2)
    t2 = _pad_to(t2.astype(jnp.float32), 128, axis=2)
    t1 = _pad_to(t1, 128, axis=1)
    t2 = _pad_to(t2, 128, axis=1)
    w1 = _pad_to(w1.astype(jnp.float32), 128, axis=1)
    w2 = _pad_to(w2.astype(jnp.float32), 128, axis=1)
    t1 = _pad_to(t1, block_p, axis=0)
    t2 = _pad_to(t2, block_p, axis=0)
    w1 = _pad_to(w1, block_p, axis=0)
    w2 = _pad_to(w2, block_p, axis=0)
    out = _sk.sinkhorn_wmd_pallas(
        t1, w1, t2, w2,
        eps=eps, eps_scaling=eps_scaling, eps_start=eps_start,
        max_iters=max_iters, tol=tol, block_p=block_p,
        bf16_matmul=bf16_matmul, interpret=interpret,
    )
    return out[:p]


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True, block_q: int = 512, block_k: int = 512,
    interpret: bool | None = None,
) -> Array:
    """Fused causal GQA attention (flash). q (B,S,Hq,D); k/v (B,T,Hkv,D)."""
    if interpret is None:
        interpret = _on_cpu()
    b, s, hq, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, k.shape[1])
    # pad seq dims to block multiples; padded kv columns are masked by causal
    # position math only when causal; for non-causal, mask via -inf keys.
    assert s % bq == 0 and k.shape[1] % bk == 0, "pad seqs to block multiple"
    return _fa.flash_attention_pallas(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_out", "interpret"))
def segment_spmm(
    src: Array,   # (E,) int32
    dst: Array,   # (E,) int32, sorted ascending (CSR edge order)
    feat: Array,  # (N, D) float
    rad: Array,   # (E,) float (0 at padding edges)
    n_out: int,
    *,
    interpret: bool | None = None,
) -> Array:
    """Fused GNN gather-scale-scatter: out[n] = sum_{dst=n} rad*feat[src].

    Zero-degree output rows are masked to 0 (unvisited blocks are undefined
    in the revisit-accumulate pattern). Feature dim padded to lane width.
    """
    if interpret is None:
        interpret = _on_cpu()
    d0 = feat.shape[1]
    feat_p = _pad_to(feat.astype(jnp.float32), 128, axis=1)
    meta = jnp.stack([src, dst]).astype(jnp.int32)
    out = _seg.segment_spmm_pallas(
        meta, feat_p, rad.astype(jnp.float32)[None, :], n_out,
        interpret=interpret)
    deg = jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst,
                              num_segments=n_out)
    return jnp.where(deg[:, None] > 0, out[:, :d0], 0.0)
