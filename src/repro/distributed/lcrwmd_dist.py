"""Distributed LC-RWMD over a (pod, data, model) TPU mesh.

Sharding (the paper's "replicate the smaller set, distribute the larger",
Sec. V/VI, expressed as mesh axes):

  resident docs (ids, weights)  -> rows over (pod, data)    [the big set]
  embedding table E             -> rows (vocab) over model  [v_e x m]
  query batch                   -> replicated

Collective schedule per query batch (B queries, k results):
  1. query-embedding gather:  psum over model of masked local rows — O(B·h·m)
  2. phase 1 (fused kernel):  NO collective — Z stays vocab-sharded
  3. phase 2 partial SpMM:    psum over model — O(n_local·B)
  4. top-k merge:             all_gather over (pod, data) of (B, k) pairs

Total cross-pod traffic is only step 4's k-sized payload — "the associated
communication cost is typically marginal" (paper Sec. V) — which is what
makes the `pod` axis safe for DCN-speed links.
"""

from __future__ import annotations

import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import numpy as np

from repro.compat import shard_map as compat_shard_map
from repro.obs import sentinel as _sentinel
from repro.core.distances import dists, safe_sqrt, sq_dists
from repro.core.topk import (
    StreamingTopK,
    TopK,
    crossshard_topk,
    distributed_topk,
    topk_smallest_cols,
)
from repro.data.docs import DocSet
from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS

Array = jax.Array
_INF = 3.4e38

# Module-level cache of compiled serve-step callables.  Historically every
# `build_serve_step` call created fresh `@jax.jit` objects, so each engine
# swap / adaptive-budget rebuild / tenant switch re-traced from scratch even
# when the mesh, shapes, and static config were identical.  Keying the step
# on (mesh, static config) — with ALL resident state passed as traced
# arguments (including the live-row mask) — lets same-shaped corpora share
# one trace: multi-tenant engine caches hit this instead of XLA.
_STEP_CACHE: dict = {}

#: Count of engine-less `build_serve_step` calls (sentinel key suffix —
#: each such build mints fresh jit objects that cannot share traces).
_ENGINELESS_BUILDS = 0


def _mesh_key(mesh) -> tuple:
    return (
        tuple(mesh.axis_names),
        tuple(int(mesh.shape[a]) for a in mesh.axis_names),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def _slab_geometry(
    n_rows: int, n_batch_shards: int, row_block: int, psum_batch: int,
    streaming: bool,
) -> tuple[int, int, int]:
    """(rb, g, row_mult): slab rows, slabs per collective, row pad multiple.

    ``g`` is the psum batching factor: the streaming scan evaluates ``g``
    consecutive ``rb``-row slabs per scan step and reduces them with ONE
    model-axis psum of the stacked (g·rb, B) partial — one collective (and
    one carry fold) per ``g`` slabs instead of per slab, at a peak-memory
    cost of (g·rb, B) instead of (rb, B).  Results are exactly equal: psum
    is elementwise and the streaming top-k fold is grouping-invariant.
    """
    rows_per_shard = max(1, -(-n_rows // n_batch_shards))
    rb = max(1, min(row_block, rows_per_shard))
    g = max(1, min(psum_batch, -(-rows_per_shard // rb))) if streaming else 1
    return rb, g, n_batch_shards * (rb * g if streaming else 1)


def _pad_rows_mult(x, mult: int, value=0):
    """Zero-pad the leading axis of ``x`` up to a multiple of ``mult``."""
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


class ServeResult(NamedTuple):
    topk: TopK        # (B, k) replicated: global doc ids + distances
    d_local: Array | None  # (n_local, B) shard distances (None when the
    #                        streaming accumulator never materializes them)
    pruned_exact: Array | None = None  # (B,) bool, rerank_wmd engine path:
    #                        True → WMD top-k provably equals the full-corpus
    #                        WMD top-k (candidate RWMD bound beat the cutoff)
    tier: int = 0     # QualityTier the batch was served at (python int,
    #                        stamped outside jit; 0 = full configured cascade)


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in (POD_AXIS, DATA_AXIS))


def _z_from_t(
    emb_local: Array, t_q: Array, q_valid: Array, *, bf16_matmul: bool = False
) -> Array:
    """Phase 1 against a local vocab shard: Z (v_local, B), distances."""
    v_l, m = emb_local.shape
    b, h, _ = t_q.shape
    sq = sq_dists(emb_local, t_q.reshape(b * h, m), bf16_matmul=bf16_matmul)
    sq = jnp.where(q_valid.reshape(-1)[None, :] > 0, sq, _INF)
    return safe_sqrt(jnp.min(sq.reshape(v_l, b, h), axis=2))


def _gather_query_embeddings(
    q_ids: Array, emb_local: Array, v_local: int
) -> Array:
    """E[q_ids] with E row-sharded over `model`: mask-gather-psum. (B,h,m)."""
    mi = jax.lax.axis_index(MODEL_AXIS)
    lo = (mi * v_local).astype(jnp.int32)
    rel = q_ids - lo
    inb = (rel >= 0) & (rel < v_local)
    local = emb_local[jnp.clip(rel, 0, v_local - 1)]  # (B, h, m)
    local = jnp.where(inb[..., None], local, 0.0)
    return jax.lax.psum(local, MODEL_AXIS)


def _phase2_partial(
    r_ids: Array, r_w: Array, z_local: Array, v_local: int
) -> Array:
    """Masked local ELL-SpMM contribution; full D after psum over model."""
    mi = jax.lax.axis_index(MODEL_AXIS)
    lo = (mi * v_local).astype(jnp.int32)
    rel = r_ids - lo
    inb = (rel >= 0) & (rel < v_local)
    zg = z_local[jnp.clip(rel, 0, v_local - 1)]  # (n_l, h, B)
    w = r_w * inb.astype(r_w.dtype)
    return jnp.einsum("nh,nhb->nb", w, zg)


def build_serve_step(
    mesh: jax.sharding.Mesh,
    *,
    k: int,
    refine: bool = False,
    bf16_matmul: bool = True,
    phase1_full_mesh: bool = True,
    engine=None,
    rerank_wmd: bool = False,
    rerank_budget: int | None = None,
    wmd_kw: dict | None = None,
    self_exclude: bool = False,
    streaming: bool | None = None,
    row_block: int = 128,
    psum_batch: int = 8,
    obs=None,
    index=None,
):
    """Returns jit'd ``serve(resident, queries, emb) -> ServeResult``.

    Shapes: ``queries`` (B, h) DocSet (keep B fixed — the compiled step is
    shape-specialized; the query servers pad to a fixed ``max_batch``) →
    ``ServeResult.topk`` (B, k) replicated TopK of global doc ids,
    ``d_local`` (n_local, B) shard distances (None when streaming), and
    ``pruned_exact`` (B,) bool (rerank path only).  Everything passed HERE
    — ``k``, ``refine``, ``rerank_wmd``/``rerank_budget``/``wmd_kw``,
    ``streaming``/``row_block``, ``self_exclude``, ``bf16_matmul``,
    ``phase1_full_mesh`` — is baked into the compiled step; changing any of
    them means building a new serve step (the servers rebuild on adaptive-
    budget changes and count it in ``stats["budget_rebuilds"]``).

    ``engine``: a prebuilt :class:`repro.core.lc_rwmd.LCRWMDEngine`.  When
    given, the returned callable is ``serve(queries) -> ServeResult``: the
    resident tensors and the (vocab-restricted, padded) embedding shards are
    prepared and placed on the mesh ONCE here, and each serve call only
    gathers the transient query embeddings from the full table — no
    per-batch re-padding or re-gathering of resident state.

    ``phase1_full_mesh`` (§Perf lcrwmd iteration 1 — beyond-paper): the
    paper's GPU mapping replicates phase 1 across the resident-data shards
    (every data row computes the same vocab-slice Z -> useful-FLOP ratio
    1/16 on a 16x16 mesh).  Instead, shard the vocabulary MODEL-major over
    the FULL mesh (each of the 256 devices scans v/256 rows), then all-gather
    Z along `data` — the gather is O(v/model * B) floats (~29 MB) against a
    16x phase-1 FLOP reduction.  ``False`` keeps the paper-faithful mapping
    (the recorded baseline).

    ``refine=True`` adds the symmetric-bound refinement: the swapped-direction
    RWMD term is evaluated with the fused pairwise kernel ONLY on the top-k
    candidates (k per query, not n), then the max-bound re-ranks them.  This
    recovers the paper's tighter max(D1, D2ᵀ) bound at serving time without
    the full second LC pass (which only pays off in all-pairs mode).

    ``rerank_wmd=True`` finishes the pruning cascade in the serve step: the
    LC-RWMD (optionally refined) top-``rerank_budget`` (default 2k) become
    candidates for ONE batched Sinkhorn-WMD call (``wmd_kw`` forwarded), and
    the final top-k is by WMD.  With an engine this routes through
    :meth:`LCRWMDEngine.rerank_topk` (pre-gathered resident embeddings feed
    the fused kernel directly); without one, through the jnp batched solver.

    ``self_exclude=True`` (engine path only) is the corpus-analytics mode:
    the returned callable becomes ``serve(queries, query_ids)`` where
    ``query_ids`` (B,) are the queries' GLOBAL resident-doc ids, and each
    query's own resident row is masked to +inf inside the streaming
    accumulator before any candidate leaves the shard — tiles of the corpus
    can stream through the serve step as query batches without self-matches
    eating a candidate slot (see
    :func:`repro.workloads.corpus_distance.corpus_self_topk_distributed`).

    ``streaming`` (engine path; default True) fuses candidate selection into
    the per-shard phase-2 accumulator: resident rows are scanned in
    ``row_block`` slabs, each slab's psum'd distances fold into a
    :class:`~repro.core.topk.StreamingTopK` carry, and the cross-shard
    top-k collective consumes the (B, k)-sized per-shard partials — the
    (n_shard, B) distance block is never materialized (O(n·B) → O(k·B) peak
    serve-path memory per device) and ``ServeResult.d_local`` is None.
    ``streaming=False`` keeps the materialized path with its ``d_local``
    diagnostics; results are identical either way, ties included.  The
    engine-less path is the paper-faithful materialized baseline and
    rejects ``streaming=True``.

    ``psum_batch`` (streaming path) batches the per-slab model-axis psums:
    ``psum_batch`` consecutive ``row_block`` slabs are reduced with ONE
    collective of the stacked (psum_batch·row_block, B) partials per scan
    step — cutting collective launch count by that factor on small row
    blocks, at proportionally higher (still O(row_block·B)) slab memory.
    Exactly equal results (psum is elementwise; the top-k fold is
    grouping-invariant).

    ``engine`` may also be a :class:`repro.core.lc_rwmd.SegmentedEngine`:
    the serve step then scans base + delta segments back-to-back inside the
    shard kernel — each segment phase-1s against its OWN restricted vocab,
    streams phase-2 slabs with its tombstone mask and per-segment
    self-exclusion applied locally, and folds (distance, global id)
    candidates into one shared carry — before the single cross-shard top-k
    collective.  The returned callable re-places segment tensors whenever
    ``engine.version`` changes, so ingest/delete/compact are admissible
    between batches: deletes only change the traced live-mask VALUES (no
    re-trace), and appends re-trace only for segment-shape signatures not
    yet seen (pad deltas via ``delta_pad``/``vocab_pad`` to pin the shapes).

    The ENGINE-path callable additionally accepts a keyword-only
    ``tier=`` (:class:`repro.core.pipeline.QualityTier`): the serving
    plane's degradation ladder.  Tier 0 is the full configured cascade;
    tier 1 serves the LC-RWMD candidates directly (the SAME compiled
    phase-1/2 step — shedding the refine/rerank stages never re-traces);
    tier 2 answers from a WCD centroid shortlist via a module-level
    ``(k, self_exclude)``-keyed jit cache.  ``ServeResult.tier`` records
    the tier a batch was served at.

    ``obs``: an optional :class:`repro.obs.Observability` bundle.  The
    engine-path callables then record per-flush serve-step host time
    (``serve_step_host_seconds`` histogram) and, once per build, the
    step's mesh-collective counts from jaxpr inspection
    (``serve_step_collectives_*`` gauges) — so a collective-schedule
    regression shows up in a metrics diff, not a profiler session.

    ``index``: a :class:`repro.index.ClusterIndex` over the (segmented)
    ``engine``.  The serve step then ROUTES each batch: the index's host
    routing stage picks the batch's probed cells (top-p by centroid
    distance, triangle-bound pruned), and the compiled step scans ONLY
    those cells through ``index.probe_cap`` jit-static probe slots —
    phase 1 runs per probed cell against that cell's restricted vocab and
    phase 2 streams only routed rows, so per-query work drops from O(n)
    to O(n/cells · p).  Batches with different probed-cell SETS reuse one
    trace (slots are sliced dynamically from the stacked cell tensors);
    only a cell-shape change (index rebuild/growth) compiles a new step.
    """
    batch_axes = _batch_axes(mesh)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    n_model = mesh.shape[MODEL_AXIS]
    # With reranking on, the mesh top-k stage widens to the candidate budget
    # and the batched WMD stage narrows back down to k.  The budget can
    # never exceed the resident corpus (pipeline clamps its analogue the
    # same way); the engine path clamps here, the engine-less path clamps
    # at trace time when the resident shapes are known.
    kc = (rerank_budget or 2 * k) if rerank_wmd else k
    kc = max(kc, k)  # the rerank stage must keep at least k candidates
    if engine is not None:
        kc = min(kc, engine.n_docs if hasattr(engine, "segments")
                 else engine.resident.n_docs)

    if index is not None:
        if engine is None or not hasattr(engine, "segments"):
            raise ValueError(
                "a ClusterIndex serve step needs a SegmentedEngine "
                "(the index's cells are engine segments)")
        if streaming is False:
            raise ValueError(
                "the routed serve step is streaming-only (d_local "
                "diagnostics are a monolithic-engine feature)")
        return _build_routed_serve_step(
            mesh, engine, index, k=k, kc=kc, refine=refine,
            bf16_matmul=bf16_matmul, phase1_full_mesh=phase1_full_mesh,
            batch_axes=batch_axes, n_batch_shards=n_batch_shards,
            n_model=n_model, rerank_wmd=rerank_wmd, wmd_kw=wmd_kw,
            self_exclude=self_exclude, row_block=row_block,
            psum_batch=psum_batch, obs=obs,
        )
    if engine is not None and hasattr(engine, "segments"):
        if streaming is False:
            raise ValueError(
                "the segmented serve step is streaming-only (d_local "
                "diagnostics are a monolithic-engine feature)")
        return _build_segmented_serve_step(
            mesh, engine, k=k, kc=kc, refine=refine, bf16_matmul=bf16_matmul,
            phase1_full_mesh=phase1_full_mesh, batch_axes=batch_axes,
            n_batch_shards=n_batch_shards, n_model=n_model,
            rerank_wmd=rerank_wmd, wmd_kw=wmd_kw, self_exclude=self_exclude,
            row_block=row_block, psum_batch=psum_batch, obs=obs,
        )
    if engine is not None:
        return _build_engine_serve_step(
            mesh, engine, k=k, kc=kc, refine=refine, bf16_matmul=bf16_matmul,
            phase1_full_mesh=phase1_full_mesh, batch_axes=batch_axes,
            n_batch_shards=n_batch_shards, n_model=n_model,
            rerank_wmd=rerank_wmd, wmd_kw=wmd_kw, self_exclude=self_exclude,
            streaming=streaming if streaming is not None else True,
            row_block=row_block, psum_batch=psum_batch, obs=obs,
        )
    if self_exclude:
        raise ValueError("self_exclude requires an engine-backed serve step")
    if streaming:
        raise ValueError("streaming top-k requires an engine-backed serve step")

    def kernel(r_ids, r_w, q_ids, q_w, emb_local):
        v_local = emb_local.shape[0]
        n_local = r_ids.shape[0]
        if phase1_full_mesh:
            # emb rows sharded (MODEL major, then batch axes): shard
            # (m, d0, d1...) owns rows [(m*D + d)*v_local, ...).
            didx = jnp.int32(0)
            for a in batch_axes:
                didx = didx * mesh.shape[a] + jax.lax.axis_index(a)
            mi = jax.lax.axis_index(MODEL_AXIS)
            lo = (mi * n_batch_shards + didx) * v_local
            # query embedding gather: mask + psum over the whole mesh
            rel = q_ids - lo
            inb = (rel >= 0) & (rel < v_local)
            t_q = emb_local[jnp.clip(rel, 0, v_local - 1)]
            t_q = jnp.where(inb[..., None], t_q, 0.0)
            for a in batch_axes:
                t_q = jax.lax.psum(t_q, a)
            t_q = jax.lax.psum(t_q, MODEL_AXIS)
            # phase 1 on this device's v/256 slice, then re-assemble the
            # model-axis slice by gathering along the batch axes.
            z_local = _z_from_t(emb_local, t_q, q_w, bf16_matmul=bf16_matmul)
            for a in reversed(batch_axes):
                z_local = jax.lax.all_gather(z_local, a, axis=0, tiled=True)
            # z_local now covers rows [mi*v/model, (mi+1)*v/model)
            partial = _phase2_partial(r_ids, r_w, z_local,
                                      v_local * n_batch_shards)
        else:
            t_q = _gather_query_embeddings(q_ids, emb_local, v_local)
            z_local = _z_from_t(emb_local, t_q, q_w, bf16_matmul=bf16_matmul)
            partial = _phase2_partial(r_ids, r_w, z_local, v_local)
        d_local = jax.lax.psum(partial, MODEL_AXIS)  # (n_l, B)

        # Global row offset of this shard: row-major over (pod, data).
        offset = jnp.int32(0)
        for a in batch_axes:
            offset = offset * mesh.shape[a] + jax.lax.axis_index(a)
        offset = offset * n_local

        tk = distributed_topk(
            d_local, min(kc, n_local * n_batch_shards),
            axis_names=batch_axes, shard_offset=offset)
        return (tk.dists, tk.indices), d_local

    rspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    if phase1_full_mesh:
        espec = P((MODEL_AXIS,) + batch_axes, None)
    else:
        espec = P(MODEL_AXIS, None)
    qspec = P(None, None)

    shmapped = compat_shard_map(
        kernel,
        mesh=mesh,
        in_specs=(rspec, rspec, qspec, qspec, espec),
        out_specs=((P(None, None), P(None, None)), rspec),
    )

    @jax.jit
    def serve(resident: DocSet, queries: DocSet, emb: Array) -> ServeResult:
        (tk_d, tk_i), d_local = shmapped(
            resident.ids, resident.weights, queries.ids, queries.weights, emb
        )
        tk = TopK(tk_d, tk_i)
        if refine:
            tk = _symmetric_refine(resident, queries, emb, tk)
        if rerank_wmd:
            tk = _wmd_rerank(resident, queries, emb, tk, k, wmd_kw)
        return ServeResult(topk=tk, d_local=d_local)

    # Engine-less builds mint a FRESH jit object each call, so traces can
    # never be shared across builds — meter each under its own key (a
    # re-trace of a seen signature within one build is still the bug).
    global _ENGINELESS_BUILDS
    _ENGINELESS_BUILDS += 1
    return _sentinel.wrap(
        f"serve_step.engineless#{_ENGINELESS_BUILDS}", serve)


def _engine_step(
    mesh, *, kc, streaming, rb, g, self_exclude, bf16_matmul,
    phase1_full_mesh,
):
    """Compiled monolithic-engine shard step from the module-level cache.

    Every piece of resident state — ids, weights, the LIVE-row mask, query
    tensors and embedding shards — is a *traced argument*, so one cached
    step serves every same-shaped corpus: engine swaps (multi-tenant cache
    readmits) and row tombstones change values, never traces.  The live
    mask subsumes the old ``row < n_real`` padding closure.
    """
    key = ("mono", _mesh_key(mesh), kc, streaming, rb, g, self_exclude,
           bf16_matmul, phase1_full_mesh)
    step = _STEP_CACHE.get(key)
    if step is not None:
        return step

    batch_axes = _batch_axes(mesh)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]

    def _z_and_span(t_q, q_valid, emb_local):
        """Phase-1 Z for this shard's vocab span (+ the span size)."""
        v_local = emb_local.shape[0]
        z_local = _z_from_t(emb_local, t_q, q_valid, bf16_matmul=bf16_matmul)
        if phase1_full_mesh:
            for a in reversed(batch_axes):
                z_local = jax.lax.all_gather(z_local, a, axis=0, tiled=True)
            return z_local, v_local * n_batch_shards
        return z_local, v_local

    def _shard_offset(n_local):
        offset = jnp.int32(0)
        for a in batch_axes:
            offset = offset * mesh.shape[a] + jax.lax.axis_index(a)
        return offset * n_local

    def kernel(rids, rw, r_live, t_q, q_valid, q_gid, emb_local):
        n_local = rids.shape[0]
        z_local, v_span = _z_and_span(t_q, q_valid, emb_local)
        partial = _phase2_partial(rids, rw, z_local, v_span)
        d_local = jax.lax.psum(partial, MODEL_AXIS)  # (n_l, B)
        offset = _shard_offset(n_local)

        # Padded alignment rows AND tombstoned docs arrive as live=False.
        d_local = jnp.where(r_live[:, None], d_local, _INF)
        if self_exclude:
            # Corpus mode: each query IS a resident doc; its own row must
            # not consume a candidate slot.  Masked locally (only the shard
            # owning the row sees a match), before the top-k collective.
            row = offset + jnp.arange(n_local, dtype=jnp.int32)
            d_local = jnp.where(row[:, None] == q_gid[None, :], _INF, d_local)

        tk = distributed_topk(d_local, kc, axis_names=batch_axes,
                              shard_offset=offset)
        return (tk.dists, tk.indices), d_local

    def kernel_streaming(rids, rw, r_live, t_q, q_valid, q_gid, emb_local):
        n_local, h1 = rids.shape
        b = t_q.shape[0]
        z_local, v_span = _z_and_span(t_q, q_valid, emb_local)
        offset = _shard_offset(n_local)

        # `g` rb-row slabs are evaluated per scan step and reduced with ONE
        # model-axis psum of the stacked (g·rb, B) partial — one collective
        # (and one carry fold) per g slabs (see _slab_geometry).
        blk = rb * g
        nb = n_local // blk
        ids_b = rids.reshape(nb, blk, h1)
        w_b = rw.reshape(nb, blk, h1)
        live_b = r_live.reshape(nb, blk)
        los = offset + jnp.arange(nb, dtype=jnp.int32) * blk
        stk = StreamingTopK(min(kc, n_local))

        def body(carry, xs):
            ids_blk, w_blk, live_blk, lo = xs
            partial = _phase2_partial(ids_blk, w_blk, z_local, v_span)
            d_blk = jax.lax.psum(partial, MODEL_AXIS)    # (g·rb, B)
            row = lo + jnp.arange(blk, dtype=jnp.int32)  # GLOBAL doc ids
            d_blk = jnp.where(live_blk[:, None], d_blk, _INF)
            if self_exclude:
                d_blk = jnp.where(
                    row[:, None] == q_gid[None, :], _INF, d_blk)
            return stk.update_cols(carry, d_blk, row), None

        local_tk, _ = jax.lax.scan(
            body, stk.init(b), (ids_b, w_b, live_b, los))
        tk = crossshard_topk(local_tk, kc, axis_names=batch_axes)
        return tk.dists, tk.indices

    rspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    lspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
    espec = (P((MODEL_AXIS,) + batch_axes, None) if phase1_full_mesh
             else P(MODEL_AXIS, None))
    in_specs = (rspec, rspec, lspec, P(None, None, None), P(None, None),
                P(None), espec)
    if streaming:
        shmapped = compat_shard_map(
            kernel_streaming, mesh=mesh, in_specs=in_specs,
            out_specs=(P(None, None), P(None, None)),
        )

        @jax.jit
        def step(rids, rw, r_live, t_q, q_valid, q_gid, emb_s):
            tk_d, tk_i = shmapped(
                rids, rw, r_live, t_q, q_valid, q_gid, emb_s)
            return TopK(tk_d, tk_i), None
    else:
        shmapped = compat_shard_map(
            kernel, mesh=mesh, in_specs=in_specs,
            out_specs=((P(None, None), P(None, None)), rspec),
        )

        @jax.jit
        def step(rids, rw, r_live, t_q, q_valid, q_gid, emb_s):
            (tk_d, tk_i), d_local = shmapped(
                rids, rw, r_live, t_q, q_valid, q_gid, emb_s)
            return TopK(tk_d, tk_i), d_local

    # Sentinel-metered: the WHOLE point of this cache is that same-shaped
    # serves reuse one trace — a re-trace here is the PR 5 bug class.
    step = _sentinel.wrap(f"step_cache.mono[kc={kc}]", step)
    _STEP_CACHE[key] = step
    return step


def _obs_step_instrument(obs, variant):
    """Resolve per-build serve-step observability handles.

    Returns ``(hist, probe)``: ``hist`` observes host wall time of each
    compiled-step call (``serve_step_host_seconds{variant=...}``), and
    ``probe(step, args)`` — called lazily on the FIRST step invocation of
    this build — records the step's structural collective counts
    (``serve_step_collectives_*`` gauges) from its jaxpr, so e.g. the
    psum-batching win of PR 7 is a visible metric instead of profiler
    archaeology.  Both are ``None`` when ``obs`` is absent.
    """
    if obs is None or getattr(obs, "metrics", None) is None:
        return None, None
    hist = obs.metrics.histogram(
        "serve_step_host_seconds",
        "Host wall time of one compiled serve-step call (async dispatch "
        "returns futures; device time lands in device_compute spans).",
        labels={"variant": variant})
    done = [False]

    def probe(step, args):
        if done[0] or not obs.metrics.enabled:
            return
        done[0] = True  # never retried, even on failure
        try:
            from repro.obs import jaxpr_collective_counts
            with _sentinel.expect("jaxpr collective inspection"):
                counts = jaxpr_collective_counts(
                    getattr(step, "__wrapped__", step), *args)
            for cname, n in counts.items():
                obs.metrics.gauge(
                    f"serve_step_collectives_{cname}",
                    "Collective ops issued per serve-step call "
                    "(structural jaxpr count; scan bodies multiplied "
                    "by trip count).",
                    labels={"variant": variant}).set(n)
        except Exception:
            pass  # inspection is best-effort; serving must not care
    return hist, probe


def _build_engine_serve_step(
    mesh, engine, *, k, kc, refine, bf16_matmul, phase1_full_mesh,
    batch_axes, n_batch_shards, n_model, rerank_wmd=False, wmd_kw=None,
    self_exclude=False, streaming=True, row_block=128, psum_batch=8,
    obs=None,
):
    """Engine-backed serve step: resident state prepped + placed at build.

    Phase 1 runs against the engine's RESTRICTED vocabulary (resident-used
    rows only — the paper's v_e optimization), while query embeddings are
    gathered from the FULL table outside the mesh kernel, so out-of-resident
    -vocab query words remain exact.  Padded resident rows are masked to
    +inf before top-k.

    With ``streaming=True`` the shard kernel never forms its (n_local, B)
    distance block: phase-2 runs in ``row_block`` slabs, each slab is
    psum'd over the model axis, row-masked (doc padding + self-exclusion)
    and folded into a per-query :class:`~repro.core.topk.StreamingTopK`
    carry, and :func:`~repro.core.topk.crossshard_topk` merges the (B, k)
    per-shard partials — the same collective, fed from O(k)-sized payloads.
    """
    from jax.sharding import NamedSharding

    n_real = engine.resident.n_docs
    # Streaming scans shard rows in (psum_batch · row_block)-row super-slabs:
    # pad the doc axis so every shard holds a whole number of them (padding
    # rows are live=False in the traced mask).
    rb, g, row_mult = _slab_geometry(
        n_real, n_batch_shards, row_block, psum_batch, streaming)
    emb_shards = n_model * (n_batch_shards if phase1_full_mesh else 1)
    emb_r = _pad_rows_mult(engine.emb_restricted, emb_shards)
    r_ids = _pad_rows_mult(engine.resident_restricted.ids, row_mult)
    r_w = _pad_rows_mult(engine.resident_restricted.weights, row_mult)
    r_live = jnp.arange(r_ids.shape[0], dtype=jnp.int32) < n_real

    rspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    lspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
    espec = (P((MODEL_AXIS,) + batch_axes, None) if phase1_full_mesh
             else P(MODEL_AXIS, None))
    r_ids = jax.device_put(r_ids, NamedSharding(mesh, rspec))
    r_w = jax.device_put(r_w, NamedSharding(mesh, rspec))
    r_live = jax.device_put(r_live, NamedSharding(mesh, lspec))
    emb_r = jax.device_put(emb_r, NamedSharding(mesh, espec))

    step = _engine_step(
        mesh, kc=kc, streaming=streaming, rb=rb, g=g,
        self_exclude=self_exclude, bf16_matmul=bf16_matmul,
        phase1_full_mesh=phase1_full_mesh)

    # Tier-2 (WCD shortlist) state: resident centroids, computed ONCE from
    # the engine's pre-gathered resident word embeddings.  The step itself
    # lives in the module-level (k, self_exclude)-keyed jit cache, so tier
    # switches — and budget-driven serve-step rebuilds — never re-trace it.
    n_docs, h1_r = engine.resident.ids.shape
    cent_r = jnp.einsum(
        "nh,nhm->nm", engine.resident.weights,
        engine._t_r.reshape(n_docs, h1_r, -1))

    _m_step, _probe = _obs_step_instrument(obs, "mono")

    def serve(queries: DocSet, query_ids=None, *, tier: int = 0) -> ServeResult:
        """Tiered serve: ``tier`` walks the degradation ladder (see
        :class:`repro.core.pipeline.QualityTier`).  Tier 0 is the full
        configured cascade; tier 1 serves the LC-RWMD candidates directly
        (refine + rerank shed — the SAME compiled phase-1/2 step, no
        re-trace); tier 2 answers from the WCD centroid shortlist only."""
        if self_exclude and query_ids is None:
            raise ValueError("self_exclude serve step needs query_ids (B,)")
        tier = int(tier)
        t_q = engine.gather_queries(queries.ids)
        q_valid = (queries.weights > 0).astype(jnp.float32)
        q_gid = (jnp.asarray(query_ids, jnp.int32) if self_exclude
                 else jnp.full((queries.n_docs,), -1, jnp.int32))
        if tier >= 2:  # QualityTier.WCD
            tk = _wcd_topk_step(k, self_exclude, cent_r, t_q,
                                queries.weights, q_gid)
            return ServeResult(topk=tk, d_local=None, pruned_exact=None,
                               tier=tier)
        step_args = (r_ids, r_w, r_live, t_q, q_valid, q_gid, emb_r)
        if _probe is not None:
            _probe(step, step_args)
        _t_step = time.perf_counter()
        tk, d_local = step(*step_args)
        if _m_step is not None:
            _m_step.observe(time.perf_counter() - _t_step)
        if tier >= 1:  # QualityTier.LCRWMD: candidates ARE the answer
            tk = TopK(tk.dists[:, :k], tk.indices[:, :k])
            return ServeResult(
                topk=tk,
                d_local=None if d_local is None else d_local[:n_real],
                pruned_exact=None, tier=tier)
        # Largest candidate RWMD: every non-candidate's WMD is >= this
        # (candidates are the kc smallest lower bounds), so it certifies
        # rerank exactness against the k-th WMD cutoff below.
        cand_max_rwmd = tk.dists[:, -1]
        exact = None
        if refine:
            tk = _symmetric_refine(
                engine.resident, queries, engine.emb_full, tk)
        if rerank_wmd:
            # Finish the cascade: ONE fused batched Sinkhorn-WMD call over
            # the (B, kc) candidates, fed by the engine's pre-gathered
            # resident embeddings.
            tk = engine.rerank_topk(queries, tk.indices, k,
                                    sinkhorn_kw=wmd_kw)
            exact = cand_max_rwmd >= tk.dists[:, -1]
            if kc >= n_real:  # no non-candidates exist: always exact
                exact = jnp.ones_like(exact)
        return ServeResult(
            topk=tk,
            d_local=None if d_local is None else d_local[:n_real],
            pruned_exact=exact,
        )

    return serve


def _segmented_step(
    mesh, *, kc, rbs, gs, self_exclude, bf16_matmul, phase1_full_mesh,
):
    """Compiled segmented shard step (one per segment-shape signature).

    The kernel scans every segment back-to-back INSIDE the shard: each
    segment phase-1s against its own restricted vocab shard, streams its
    phase-2 super-slabs with the traced live mask and per-segment
    self-exclusion applied locally, and folds (distance, GLOBAL id)
    candidates into one shared :class:`~repro.core.topk.StreamingTopK`
    carry — then ONE cross-shard top-k collective merges the per-shard
    partials, exactly like the monolithic step.  ``rbs``/``gs`` are the
    per-segment slab geometries (their length fixes the segment count);
    everything else — tensors, live masks, id offsets — is traced, so
    deletes and same-shape delta appends reuse the cached trace.
    """
    key = ("seg", _mesh_key(mesh), kc, rbs, gs, self_exclude, bf16_matmul,
           phase1_full_mesh)
    step = _STEP_CACHE.get(key)
    if step is not None:
        return step

    n_segments = len(rbs)
    batch_axes = _batch_axes(mesh)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]

    def _z_and_span(t_q, q_valid, emb_local):
        v_local = emb_local.shape[0]
        z_local = _z_from_t(emb_local, t_q, q_valid, bf16_matmul=bf16_matmul)
        if phase1_full_mesh:
            for a in reversed(batch_axes):
                z_local = jax.lax.all_gather(z_local, a, axis=0, tiled=True)
            return z_local, v_local * n_batch_shards
        return z_local, v_local

    def _shard_offset(n_local):
        offset = jnp.int32(0)
        for a in batch_axes:
            offset = offset * mesh.shape[a] + jax.lax.axis_index(a)
        return offset * n_local

    def kernel(seg_rids, seg_rw, seg_live, seg_offs, t_q, q_valid, q_gid,
               seg_embs):
        b = t_q.shape[0]
        total_local = sum(r.shape[0] for r in seg_rids)
        stk = StreamingTopK(min(kc, total_local))
        carry = stk.init(b)
        for s in range(n_segments):
            rids, rw, live = seg_rids[s], seg_rw[s], seg_live[s]
            n_local, h1 = rids.shape
            z_local, v_span = _z_and_span(t_q, q_valid, seg_embs[s])
            # Rows of this shard's slice of segment s own the global ids
            # [seg_offs[s] + shard_off, ...) — offsets are traced, so
            # compaction's offset rewrite reuses the cached trace too.
            row0 = seg_offs[s] + _shard_offset(n_local)
            blk = rbs[s] * gs[s]
            nb = n_local // blk
            ids_b = rids.reshape(nb, blk, h1)
            w_b = rw.reshape(nb, blk, h1)
            live_b = live.reshape(nb, blk)
            los = row0 + jnp.arange(nb, dtype=jnp.int32) * blk

            def body(carry, xs, z_local=z_local, v_span=v_span, blk=blk):
                ids_blk, w_blk, live_blk, lo = xs
                partial = _phase2_partial(ids_blk, w_blk, z_local, v_span)
                d_blk = jax.lax.psum(partial, MODEL_AXIS)    # (g·rb, B)
                row = lo + jnp.arange(blk, dtype=jnp.int32)  # GLOBAL ids
                d_blk = jnp.where(live_blk[:, None], d_blk, _INF)
                if self_exclude:
                    d_blk = jnp.where(
                        row[:, None] == q_gid[None, :], _INF, d_blk)
                return stk.update_cols(carry, d_blk, row), None

            carry, _ = jax.lax.scan(body, carry, (ids_b, w_b, live_b, los))
        tk = crossshard_topk(carry, kc, axis_names=batch_axes)
        return tk.dists, tk.indices

    rspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    lspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
    espec = (P((MODEL_AXIS,) + batch_axes, None) if phase1_full_mesh
             else P(MODEL_AXIS, None))
    seg = lambda spec: tuple(spec for _ in range(n_segments))  # noqa: E731
    shmapped = compat_shard_map(
        kernel, mesh=mesh,
        in_specs=(seg(rspec), seg(rspec), seg(lspec), P(None),
                  P(None, None, None), P(None, None), P(None), seg(espec)),
        out_specs=(P(None, None), P(None, None)),
    )

    @jax.jit
    def step(seg_rids, seg_rw, seg_live, seg_offs, t_q, q_valid, q_gid,
             seg_embs):
        tk_d, tk_i = shmapped(seg_rids, seg_rw, seg_live, seg_offs,
                              t_q, q_valid, q_gid, seg_embs)
        return TopK(tk_d, tk_i)

    step = _sentinel.wrap(
        f"step_cache.seg[kc={kc},segs={n_segments}]", step)
    _STEP_CACHE[key] = step
    return step


def _build_segmented_serve_step(
    mesh, engine, *, k, kc, refine, bf16_matmul, phase1_full_mesh,
    batch_axes, n_batch_shards, n_model, rerank_wmd=False, wmd_kw=None,
    self_exclude=False, row_block=128, psum_batch=8, obs=None,
):
    """Serve step over a :class:`~repro.core.lc_rwmd.SegmentedEngine`.

    Per-segment resident tensors (ids, weights, live masks, restricted
    embedding shards) are placed on the mesh lazily and re-placed whenever
    ``engine.version`` changes, so the SAME callable keeps serving across
    ingest/delete/compact — no rebuild, and no re-trace unless the segment
    shape signature is new.  Tier-2 centroids are refreshed on the same
    version check with tombstoned rows pushed to an unreachable distance.
    """
    from jax.sharding import NamedSharding

    rspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    lspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
    espec = (P((MODEL_AXIS,) + batch_axes, None) if phase1_full_mesh
             else P(MODEL_AXIS, None))
    emb_shards = n_model * (n_batch_shards if phase1_full_mesh else 1)
    state: dict = {"version": None}
    _m_step, _probe = _obs_step_instrument(obs, "seg")

    def _refresh():
        if state["version"] == engine.version:
            return
        if not engine.segments:
            raise ValueError("segmented serve step needs a non-empty engine")
        rbs, gs, rids, rw, live, embs, offs = [], [], [], [], [], [], []
        for seg, lv in zip(engine.segments, engine._live):
            rb_s, g_s, row_mult = _slab_geometry(
                seg.n_rows, n_batch_shards, row_block, psum_batch, True)
            lv_pad = np.zeros(
                seg.n_rows + (-seg.n_rows) % row_mult, dtype=bool)
            lv_pad[:seg.n_rows] = lv
            rbs.append(rb_s)
            gs.append(g_s)
            rids.append(jax.device_put(
                _pad_rows_mult(seg.tensors.r_ids, row_mult),
                NamedSharding(mesh, rspec)))
            rw.append(jax.device_put(
                _pad_rows_mult(seg.tensors.r_w, row_mult),
                NamedSharding(mesh, rspec)))
            live.append(jax.device_put(
                jnp.asarray(lv_pad), NamedSharding(mesh, lspec)))
            embs.append(jax.device_put(
                _pad_rows_mult(seg.tensors.emb_r, emb_shards),
                NamedSharding(mesh, espec)))
            offs.append(seg.offset)
        state["step"] = _segmented_step(
            mesh, kc=kc, rbs=tuple(rbs), gs=tuple(gs),
            self_exclude=self_exclude, bf16_matmul=bf16_matmul,
            phase1_full_mesh=phase1_full_mesh)
        state["rids"] = tuple(rids)
        state["rw"] = tuple(rw)
        state["live"] = tuple(live)
        state["embs"] = tuple(embs)
        state["offs"] = jnp.asarray(offs, dtype=jnp.int32)
        # Tier-2 WCD shortlist: per-segment centroids from the pre-gathered
        # resident embeddings; tombstoned rows sit at distance ~1e18 so the
        # shortlist can never surface them.
        cents = []
        for seg in engine.segments:
            n_rows, h1 = seg.docs.ids.shape
            c = jnp.einsum("nh,nhm->nm", seg.docs.weights,
                           seg.tensors.t_r.reshape(n_rows, h1, -1))
            cents.append(c[:seg.n_real])
        cent = jnp.concatenate(cents, axis=0)
        state["cent"] = jnp.where(
            engine.live_mask_device()[:, None], cent, 1e18)
        state["version"] = engine.version

    def serve(queries: DocSet, query_ids=None, *, tier: int = 0) -> ServeResult:
        """Tiered segmented serve (same ladder as the monolithic step)."""
        if self_exclude and query_ids is None:
            raise ValueError("self_exclude serve step needs query_ids (B,)")
        tier = int(tier)
        _refresh()
        t_q = engine.gather_queries(queries.ids)
        q_valid = (queries.weights > 0).astype(jnp.float32)
        q_gid = (jnp.asarray(query_ids, jnp.int32) if self_exclude
                 else jnp.full((queries.n_docs,), -1, jnp.int32))
        if tier >= 2:  # QualityTier.WCD
            tk = _wcd_topk_step(k, self_exclude, state["cent"], t_q,
                                queries.weights, q_gid)
            return ServeResult(topk=tk, d_local=None, pruned_exact=None,
                               tier=tier)
        step_args = (state["rids"], state["rw"], state["live"],
                     state["offs"], t_q, q_valid, q_gid, state["embs"])
        if _probe is not None:
            _probe(state["step"], step_args)
        _t_step = time.perf_counter()
        tk = state["step"](*step_args)
        if _m_step is not None:
            _m_step.observe(time.perf_counter() - _t_step)
        if tier >= 1:  # QualityTier.LCRWMD: candidates ARE the answer
            return ServeResult(
                topk=TopK(tk.dists[:, :k], tk.indices[:, :k]),
                d_local=None, pruned_exact=None, tier=tier)
        cand_max_rwmd = tk.dists[:, -1]
        exact = None
        if refine:
            tk = _symmetric_refine(
                engine.resident, queries, engine.emb_full, tk)
        if rerank_wmd:
            tk = engine.rerank_topk(queries, tk.indices, k,
                                    sinkhorn_kw=wmd_kw)
            exact = cand_max_rwmd >= tk.dists[:, -1]
            if kc >= engine.n_live:  # candidates cover every live doc
                exact = jnp.ones_like(exact)
        return ServeResult(topk=tk, d_local=None, pruned_exact=exact)

    return serve


def _routed_step(
    mesh, *, kc, p_max, rb, g, n_cells, self_exclude, bf16_matmul,
    phase1_full_mesh,
):
    """Compiled cluster-routed shard step (one per cell-shape signature).

    Cell tensors arrive STACKED on a leading (replicated) cell axis —
    (n_cells, rows_pad, ...) with rows sharded over the batch axes — and
    the batch's probed cells arrive as ``p_max`` jit-STATIC probe slots:
    ``probed`` (p_max,) int32 cell ids (-1 pads) plus ``q_route``
    (B, p_max) per-query slot masks.  Each slot dynamic-slices its cell
    out of the stack, phase-1s against that cell's restricted vocab
    shard, and streams phase-2 slabs masked by live ∧ routed into ONE
    shared :class:`~repro.core.topk.StreamingTopK` carry keyed by the
    cell's per-row GLOBAL ids — then one cross-shard top-k merges shard
    partials, exactly like the segmented step.  Because slot→cell binding
    is a traced VALUE, batches probing different cell subsets reuse this
    trace; pad slots are fully masked (their sliced compute is dead
    work bounded by p_max, never a correctness hazard).

    Structurally, phase-2 contractions only ever see (slab, ...) operands
    from the p_max sliced cells — nothing in the jaxpr touches all
    n_cells · rows_pad rows at once (tests/test_index.py asserts this),
    which is the O(n) → O(n/cells · p) claim in compiled form.
    """
    key = ("routed", _mesh_key(mesh), kc, p_max, rb, g, n_cells,
           self_exclude, bf16_matmul, phase1_full_mesh)
    step = _STEP_CACHE.get(key)
    if step is not None:
        return step

    batch_axes = _batch_axes(mesh)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]

    def _z_and_span(t_q, q_valid, emb_local):
        v_local = emb_local.shape[0]
        z_local = _z_from_t(emb_local, t_q, q_valid, bf16_matmul=bf16_matmul)
        if phase1_full_mesh:
            for a in reversed(batch_axes):
                z_local = jax.lax.all_gather(z_local, a, axis=0, tiled=True)
            return z_local, v_local * n_batch_shards
        return z_local, v_local

    def kernel(c_rids, c_rw, c_live, c_gids, probed, q_route, t_q, q_valid,
               q_gid, c_embs):
        b = t_q.shape[0]
        rows_local = c_rids.shape[1]
        h1 = c_rids.shape[2]
        stk = StreamingTopK(min(kc, p_max * rows_local))
        carry = stk.init(b)
        blk = rb * g
        nb = rows_local // blk
        for s in range(p_max):
            # Pad slots (probed = -1) clip to cell 0; their q_route column
            # is all-False, so every row they contribute is masked +inf.
            cid = jnp.clip(probed[s], 0, n_cells - 1)
            rids = jax.lax.dynamic_index_in_dim(c_rids, cid, 0, False)
            rw = jax.lax.dynamic_index_in_dim(c_rw, cid, 0, False)
            live = jax.lax.dynamic_index_in_dim(c_live, cid, 0, False)
            gids = jax.lax.dynamic_index_in_dim(c_gids, cid, 0, False)
            emb_c = jax.lax.dynamic_index_in_dim(c_embs, cid, 0, False)
            z_local, v_span = _z_and_span(t_q, q_valid, emb_c)
            ids_b = rids.reshape(nb, blk, h1)
            w_b = rw.reshape(nb, blk, h1)
            live_b = live.reshape(nb, blk)
            gid_b = gids.reshape(nb, blk)
            route_s = q_route[:, s]  # (B,) this slot's per-query mask

            def body(carry, xs, z_local=z_local, v_span=v_span,
                     route_s=route_s):
                ids_blk, w_blk, live_blk, gid_blk = xs
                partial = _phase2_partial(ids_blk, w_blk, z_local, v_span)
                d_blk = jax.lax.psum(partial, MODEL_AXIS)    # (g·rb, B)
                d_blk = jnp.where(
                    live_blk[:, None] & route_s[None, :], d_blk, _INF)
                if self_exclude:
                    d_blk = jnp.where(
                        gid_blk[:, None] == q_gid[None, :], _INF, d_blk)
                return stk.update_cols(carry, d_blk, gid_blk), None

            carry, _ = jax.lax.scan(
                body, carry, (ids_b, w_b, live_b, gid_b))
        tk = crossshard_topk(carry, kc, axis_names=batch_axes)
        return tk.dists, tk.indices

    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    rspec = P(None, bspec, None)       # (cells, rows, h) — rows sharded
    lspec = P(None, bspec)             # (cells, rows)
    espec = (P(None, (MODEL_AXIS,) + batch_axes, None) if phase1_full_mesh
             else P(None, MODEL_AXIS, None))
    shmapped = compat_shard_map(
        kernel, mesh=mesh,
        in_specs=(rspec, rspec, lspec, lspec, P(None), P(None, None),
                  P(None, None, None), P(None, None), P(None), espec),
        out_specs=(P(None, None), P(None, None)),
    )

    @jax.jit
    def step(c_rids, c_rw, c_live, c_gids, probed, q_route, t_q, q_valid,
             q_gid, c_embs):
        tk_d, tk_i = shmapped(c_rids, c_rw, c_live, c_gids, probed,
                              q_route, t_q, q_valid, q_gid, c_embs)
        return TopK(tk_d, tk_i)

    step = _sentinel.wrap(
        f"step_cache.routed[kc={kc},p={p_max},cells={n_cells}]", step)
    _STEP_CACHE[key] = step
    return step


def _build_routed_serve_step(
    mesh, engine, index, *, k, kc, refine, bf16_matmul, phase1_full_mesh,
    batch_axes, n_batch_shards, n_model, rerank_wmd=False, wmd_kw=None,
    self_exclude=False, row_block=128, psum_batch=8, obs=None,
):
    """Serve step routed through a :class:`repro.index.ClusterIndex`.

    Host side per batch: ``index.route`` picks each query's top-p cells
    (triangle-bound pruned), the batch's probed-cell UNION is packed into
    ``index.probe_cap`` static slots (overflow drops the least-requested
    cells, counted in ``index_probe_overflow_total``), and the compiled
    step scans only those slots.  Device state — per-cell row tensors,
    global-id maps, live masks, restricted embedding shards — is stacked
    on a leading cell axis and re-placed whenever ``engine.version`` OR
    ``index.version`` moves, so ingest (``index.add``), deletes (no index
    call at all), and compaction (``index.rebuild``) are all admissible
    between batches; only a cell-SHAPE change re-traces.
    """
    from jax.sharding import NamedSharding

    p_max = index.probe_cap
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    emb_shards = n_model * (n_batch_shards if phase1_full_mesh else 1)
    state: dict = {"key": None}
    _m_step, _probe = _obs_step_instrument(obs, "routed")

    def _refresh():
        index._sync_live()  # raises if engine grew without index.add
        key = (engine.version, index.version)
        if state["key"] == key:
            return
        rows_cap = index.rows_cap
        rb, g, row_mult = _slab_geometry(
            rows_cap, n_batch_shards, row_block, psum_batch, True)
        live = engine.live_mask()
        rids, rw, lv, gids, embs = [], [], [], [], []
        for cell in index.cells:
            t = cell.segment.tensors
            rids.append(_pad_rows_mult(t.r_ids, row_mult))
            rw.append(_pad_rows_mult(t.r_w, row_mult))
            lv_np = np.zeros(rids[-1].shape[0], dtype=bool)
            if len(cell.members):
                lv_np[:len(cell.members)] = live[cell.members]
            lv.append(jnp.asarray(lv_np))
            gids.append(_pad_rows_mult(cell.gids_dev, row_mult, value=-1))
            embs.append(_pad_rows_mult(t.emb_r, emb_shards))
        rows_pad = int(rids[0].shape[0])
        if p_max * rows_pad < k:
            raise ValueError(
                f"probe_cap={p_max} × padded cell rows {rows_pad} cannot "
                f"yield k={k} candidates; raise probe_cap or num_cells")
        state["kc"] = min(kc, p_max * rows_pad)
        state["rids"] = jax.device_put(
            jnp.stack(rids), NamedSharding(mesh, P(None, bspec, None)))
        state["rw"] = jax.device_put(
            jnp.stack(rw), NamedSharding(mesh, P(None, bspec, None)))
        state["live"] = jax.device_put(
            jnp.stack(lv), NamedSharding(mesh, P(None, bspec)))
        state["gids"] = jax.device_put(
            jnp.stack(gids), NamedSharding(mesh, P(None, bspec)))
        state["embs"] = jax.device_put(
            jnp.stack(embs), NamedSharding(
                mesh, P(None, (MODEL_AXIS,) + batch_axes, None)
                if phase1_full_mesh else P(None, MODEL_AXIS, None)))
        step = _routed_step(
            mesh, kc=state["kc"], p_max=p_max, rb=rb, g=g,
            n_cells=index.num_cells, self_exclude=self_exclude,
            bf16_matmul=bf16_matmul, phase1_full_mesh=phase1_full_mesh)
        # A DIFFERENT compiled step (first build, or a cell-shape change
        # from index growth/rebuild) legitimately traces on its next call;
        # tell the armed sentinel so.  Same-shape refreshes (deletes, live
        # churn, value-only re-placement) keep the old step — no scope.
        state["fresh"] = step is not state.get("step")
        state["step"] = step
        # Tier-2 WCD shortlist over the ENGINE's flat resident order (the
        # degradation ladder bypasses routing entirely).
        cents = []
        for seg in engine.segments:
            n_rows, h1 = seg.docs.ids.shape
            c = jnp.einsum("nh,nhm->nm", seg.docs.weights,
                           seg.tensors.t_r.reshape(n_rows, h1, -1))
            cents.append(c[:seg.n_real])
        cent = jnp.concatenate(cents, axis=0)
        state["cent"] = jnp.where(
            engine.live_mask_device()[:, None], cent, 1e18)
        state["key"] = key

    def _pack_slots(route, b):
        """Probed-cell union → (probed (p_max,), q_route (B, p_max))."""
        probed = route.probed
        keep = route.keep
        if len(probed) > p_max:
            # Slot overflow: keep the cells the most queries asked for.
            req = np.zeros(index.num_cells, dtype=np.int64)
            np.add.at(req, route.cells[keep].reshape(-1), 1)
            order = np.argsort(-req[probed], kind="stable")
            dropped = probed[order[p_max:]]
            probed = np.sort(probed[order[:p_max]])
            keep = keep & ~np.isin(route.cells, dropped)
            if (index.obs is not None
                    and getattr(index.obs.metrics, "enabled", False)):
                index.obs.metrics.counter(
                    "index_probe_overflow_total",
                    "Probed cells dropped because a batch's routed-cell "
                    "union exceeded probe_cap slots.").inc(len(dropped))
        slots = np.full(p_max, -1, dtype=np.int32)
        slots[:len(probed)] = probed
        q_route = np.zeros((b, p_max), dtype=bool)
        for s, c in enumerate(probed):
            q_route[:, s] = ((route.cells == c) & keep).any(axis=1)
        return jnp.asarray(slots), jnp.asarray(q_route)

    def serve(queries: DocSet, query_ids=None, *, tier: int = 0) -> ServeResult:
        """Tiered routed serve (same ladder as the segmented step)."""
        if self_exclude and query_ids is None:
            raise ValueError("self_exclude serve step needs query_ids (B,)")
        tier = int(tier)
        _refresh()
        t_q = engine.gather_queries(queries.ids)
        q_valid = (queries.weights > 0).astype(jnp.float32)
        q_gid = (jnp.asarray(query_ids, jnp.int32) if self_exclude
                 else jnp.full((queries.n_docs,), -1, jnp.int32))
        if tier >= 2:  # QualityTier.WCD — no routing on the last rung
            tk = _wcd_topk_step(k, self_exclude, state["cent"], t_q,
                                queries.weights, q_gid)
            return ServeResult(topk=tk, d_local=None, pruned_exact=None,
                               tier=tier)
        route = index.route(queries)
        slots, q_route = _pack_slots(route, queries.n_docs)
        step_args = (state["rids"], state["rw"], state["live"],
                     state["gids"], slots, q_route, t_q, q_valid, q_gid,
                     state["embs"])
        if _probe is not None:
            _probe(state["step"], step_args)
        _t_step = time.perf_counter()
        if state.pop("fresh", False):
            with _sentinel.expect("routed index cell-shape change"):
                tk = state["step"](*step_args)
        else:
            tk = state["step"](*step_args)
        if _m_step is not None:
            _m_step.observe(time.perf_counter() - _t_step)
        if tier >= 1:  # QualityTier.LCRWMD: candidates ARE the answer
            return ServeResult(
                topk=TopK(tk.dists[:, :k], tk.indices[:, :k]),
                d_local=None, pruned_exact=None, tier=tier)
        cand_max_rwmd = tk.dists[:, -1]
        exact = None
        if refine:
            tk = _symmetric_refine(
                engine.resident, queries, engine.emb_full, tk)
        if rerank_wmd:
            tk = engine.rerank_topk(queries, tk.indices, k,
                                    sinkhorn_kw=wmd_kw)
            # Exactness is RELATIVE TO THE ROUTED CELLS (the pipeline's
            # index-stage contract); promote to a corpus-wide certificate
            # only when routing provably covered every live doc.
            exact = cand_max_rwmd >= tk.dists[:, -1]
            if (state["kc"] >= engine.n_live
                    and route.cells.shape[1] == index.num_cells
                    and bool(route.keep.all())):
                exact = jnp.ones_like(exact)
        return ServeResult(topk=tk, d_local=None, pruned_exact=exact)

    return serve


@jax.jit
def _symmetric_refine(
    resident: DocSet, queries: DocSet, emb: Array, tk: TopK
) -> TopK:
    """Tighten D1 candidates with the swapped-direction bound (paper's
    max(D1, D2ᵀ)) evaluated only on the (B, k) candidate pairs.

    jit'd at module level (DocSet/TopK are pytrees): the per-candidate
    ``rwmd_pair`` vmap is traced once per shape, not per serve call — the
    untraced version cost ~100 ms of host time PER FLUSH, which serialized
    the async pipeline's host stage (see EXPERIMENTS.md §Serving)."""
    from repro.core.rwmd import rwmd_pair

    def per_query(q_ids, q_w, cand_idx, cand_d):
        def one(i, d1):
            d_sym = rwmd_pair(
                resident.ids[i], resident.weights[i], q_ids, q_w, emb
            )
            return jnp.maximum(d1, d_sym)

        d = jax.vmap(one)(cand_idx, cand_d)
        order = jnp.argsort(d)
        return TopK(d[order], cand_idx[order])

    return jax.vmap(per_query)(queries.ids, queries.weights, tk.indices, tk.dists)


# Module-level jit caches: the PR 5 fix made these trace once per shape —
# the sentinel keeps them honest.
_symmetric_refine = _sentinel.wrap(
    "lcrwmd_dist._symmetric_refine", _symmetric_refine)


def _wmd_rerank(
    resident: DocSet, queries: DocSet, emb: Array, tk: TopK, k: int,
    wmd_kw: dict | None,
) -> TopK:
    """Re-rank (B, budget) candidates by batched Sinkhorn-WMD; keep top-k.

    Engine-less serve path only (the engine path uses the already-jit'd
    :meth:`LCRWMDEngine.rerank_topk`).  Dispatches through a jit cache keyed
    on ``(k, wmd_kw)`` so the batched solve is traced once per shape."""
    return _wmd_rerank_jit(resident, queries, emb, tk, k,
                           tuple(sorted((wmd_kw or {}).items())))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _wcd_topk_step(
    k: int, self_exclude: bool, cent_r: Array, t_q: Array, q_w: Array,
    q_gid: Array,
) -> TopK:
    """Tier-2 degraded serve: top-k by Word Centroid Distance only.

    The cheapest rung of the cascade ladder (paper Sec. III): one (B, m)
    einsum + one (n, B) centroid-distance matrix — no phase 1/2, no mesh
    collectives (``cent_r`` is replicated; at n where WCD is the fallback
    the matrix is trivially small next to the shed stages).  Module-level
    jit keyed on ``(k, self_exclude)`` so every serve-step build — and every
    adaptive-budget rebuild — shares one trace.
    """
    c_q = jnp.einsum("bh,bhm->bm", q_w, t_q)
    d = dists(cent_r, c_q)  # (n, B)
    if self_exclude:
        row = jnp.arange(cent_r.shape[0], dtype=jnp.int32)
        d = jnp.where(row[:, None] == q_gid[None, :], _INF, d)
    return topk_smallest_cols(d, k)


_wcd_topk_step = _sentinel.wrap(
    "lcrwmd_dist._wcd_topk_step", _wcd_topk_step)


@functools.partial(jax.jit, static_argnums=(4, 5))
def _wmd_rerank_jit(
    resident: DocSet, queries: DocSet, emb: Array, tk: TopK, k: int,
    kw_items: tuple,
) -> TopK:
    from repro.core.topk import topk_from_candidates
    from repro.core.wmd import wmd_candidate_values

    flat = tk.indices.reshape(-1)
    vals = wmd_candidate_values(
        emb[resident.ids[flat]], resident.weights[flat],
        emb[queries.ids], queries.weights,
        **dict(kw_items),
    )
    return topk_from_candidates(vals, tk.indices, k)


_wmd_rerank_jit = _sentinel.wrap(
    "lcrwmd_dist._wmd_rerank_jit", _wmd_rerank_jit)


def build_allpairs_d1(
    mesh: jax.sharding.Mesh, *, bf16_matmul: bool = True,
    phase1_full_mesh: bool = True,
):
    """All-pairs one-sided LC-RWMD: D1 (n1 sharded over batch axes, n2).

    The symmetric all-pairs bound runs this twice with sets swapped and takes
    max(D1, D2ᵀ) — exactly the paper's Sec. IV procedure.  n2 plays the role
    of a query batch and is replicated; callers chunk it.
    ``phase1_full_mesh`` applies the same beyond-paper vocab sharding as the
    serve path (§Perf Cell C): 16x less redundant phase-1 work.
    """
    batch_axes = _batch_axes(mesh)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]

    def kernel(r_ids, r_w, q_ids, q_w, emb_local):
        v_local = emb_local.shape[0]
        if phase1_full_mesh:
            didx = jnp.int32(0)
            for a in batch_axes:
                didx = didx * mesh.shape[a] + jax.lax.axis_index(a)
            mi = jax.lax.axis_index(MODEL_AXIS)
            lo = (mi * n_batch_shards + didx) * v_local
            rel = q_ids - lo
            inb = (rel >= 0) & (rel < v_local)
            t_q = emb_local[jnp.clip(rel, 0, v_local - 1)]
            t_q = jnp.where(inb[..., None], t_q, 0.0)
            for a in batch_axes:
                t_q = jax.lax.psum(t_q, a)
            t_q = jax.lax.psum(t_q, MODEL_AXIS)
            z_local = _z_from_t(emb_local, t_q, q_w, bf16_matmul=bf16_matmul)
            for a in reversed(batch_axes):
                z_local = jax.lax.all_gather(z_local, a, axis=0, tiled=True)
            partial = _phase2_partial(r_ids, r_w, z_local,
                                      v_local * n_batch_shards)
        else:
            t_q = _gather_query_embeddings(q_ids, emb_local, v_local)
            z_local = _z_from_t(emb_local, t_q, q_w, bf16_matmul=bf16_matmul)
            partial = _phase2_partial(r_ids, r_w, z_local, v_local)
        return jax.lax.psum(partial, MODEL_AXIS)

    rspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    espec = (P((MODEL_AXIS,) + batch_axes, None) if phase1_full_mesh
             else P(MODEL_AXIS, None))

    shmapped = compat_shard_map(
        kernel,
        mesh=mesh,
        in_specs=(rspec, rspec, P(None, None), P(None, None), espec),
        out_specs=rspec,
    )

    @jax.jit
    def d1(set1: DocSet, set2: DocSet, emb: Array) -> Array:
        return shmapped(set1.ids, set1.weights, set2.ids, set2.weights, emb)

    return d1
