"""Corpus-analytics workloads on top of the LC-RWMD serve engine.

The paper motivates LC-RWMD with three workloads — querying, clustering,
and classifying large document sets.  ``repro.core`` + ``repro.serving``
cover querying; this package covers the corpus-vs-corpus rest:

  * :mod:`corpus_distance` — tiled all-pairs scheduling (self and
    cross-set) with running top-k merges; the (n, n) matrix never
    materializes.
  * :mod:`clustering` — greedy k-centers seeding + k-medoids refinement
    with a WCD prefilter and optional Sinkhorn-WMD rerank.
  * :mod:`neighbors` — threshold / k-NN near-duplicate graphs and
    duplicate-group extraction from the same tile stream.

All entry points take a prebuilt :class:`~repro.core.lc_rwmd.LCRWMDEngine`
(built once per corpus) and a ``tile`` knob that bounds every device
intermediate at (tile, tile) — the memory model is tabulated in
``docs/ARCHITECTURE.md`` and EXPERIMENTS.md §Workloads.
"""

from repro.workloads.clustering import (
    ClusterResult,
    adjusted_rand_index,
    kcenters,
    kmedoids,
    kmedoids_wcd_baseline,
    purity,
)
from repro.workloads.corpus_distance import (
    CorpusTopKResult,
    SelfPairScheduler,
    TileBlock,
    corpus_self_topk,
    corpus_self_topk_distributed,
    corpus_vs_corpus_topk,
)
from repro.workloads.neighbors import (
    DUPLICATE_SCORE_FLOOR,
    NeighborGraph,
    connected_components,
    duplicate_groups,
    ingest_dedup_mask,
    knn_graph,
    near_duplicate_graph,
)

__all__ = [
    "ClusterResult", "adjusted_rand_index", "kcenters", "kmedoids",
    "kmedoids_wcd_baseline", "purity",
    "CorpusTopKResult", "SelfPairScheduler", "TileBlock",
    "corpus_self_topk", "corpus_self_topk_distributed",
    "corpus_vs_corpus_topk",
    "DUPLICATE_SCORE_FLOOR", "NeighborGraph", "connected_components",
    "duplicate_groups", "ingest_dedup_mask", "knn_graph",
    "near_duplicate_graph",
]
