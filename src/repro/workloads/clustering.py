"""Document clustering on LC-RWMD: greedy k-centers + k-medoids refinement.

The paper's clustering workload (Sec. I motivates LC-RWMD for "clustering
... large sets of documents") realized on top of the serve engine:

  * :func:`kcenters` — greedy farthest-first traversal (2-approximation of
    the k-centers objective); one B=1 engine block per center.
  * :func:`kmedoids` — PAM-style alternation driven by the engine's
    resident-tile entry points.  The assignment stage runs a **WCD-centroid
    prefilter** (cheap (n, k) centroid distances, reusing
    :mod:`repro.core.wcd`) to keep only ``prefilter`` candidate medoids per
    doc, then evaluates the symmetric RWMD bound ONLY on those pairs via
    :func:`repro.core.rwmd.rwmd_pairs_from_t` — O(n·c·h²·m) instead of the
    full block's O(n·k·h²·m) swapped-direction term.  Optionally the
    assignment is re-ranked by batched Sinkhorn-WMD
    (:func:`repro.core.wmd.wmd_batched_dispatch`) on the same candidate
    pairs.  The medoid-update stage shortlists members closest to the
    cluster's WCD centroid and picks the one minimizing the summed RWMD to
    all members — all clusters' shortlists batched into ONE
    (n, k·medoid_candidates) engine block with in-device per-cluster
    membership masking.

WCD is a heuristic prefilter here, not a bound on RWMD (WCD ≤ WMD holds,
WCD ≤ RWMD does not in general); ``prefilter=None`` disables it and scores
all k medoids exactly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk as topk_lib
from repro.core.distances import dists
from repro.core.lc_rwmd import LCRWMDEngine
from repro.core.rwmd import rwmd_pairs_from_t
from repro.core.wcd import centroids_from_t
from repro.core.wmd import wmd_batched_dispatch

Array = jax.Array


class ClusterResult(NamedTuple):
    labels: np.ndarray     # (n,) int32 cluster assignment
    medoids: np.ndarray    # (k,) int32 medoid doc ids
    objective: float       # sum of assigned distances (RWMD or WMD)
    n_iters: int           # k-medoids iterations executed


def kcenters(
    engine: LCRWMDEngine, n_clusters: int, *, first: int | None = 0,
    seed: int | None = None,
) -> np.ndarray:
    """Greedy k-centers (farthest-first) seeding over the resident corpus.

    Returns (n_clusters,) int32 doc ids.  Each step adds the doc farthest
    (symmetric LC-RWMD) from the chosen set — the classic 2-approximation,
    and the standard k-medoids initializer.

    The traversal is deterministic given its starting doc: pass ``seed`` to
    derive ``first`` from an explicit PRNG (``first=None`` or ``seed``
    given), so index partitions rebuilt from the same corpus + seed land on
    identical centers — rebuild/compaction paths rely on this.
    """
    n = engine.resident.n_docs
    if not 1 <= n_clusters <= n:
        raise ValueError(f"need 1 <= n_clusters <= {n}, got {n_clusters}")
    if seed is not None or first is None:
        first = int(np.random.default_rng(0 if seed is None else seed)
                    .integers(0, n))
    centers = [int(first)]
    mind = np.full(n, np.inf, dtype=np.float32)
    for _ in range(n_clusters - 1):
        col = np.asarray(
            engine.symmetric_resident(jnp.array([centers[-1]], jnp.int32))
        )[:, 0]
        mind = np.minimum(mind, col)
        centers.append(int(np.argmax(mind)))
    return np.asarray(centers, dtype=np.int32)


@functools.partial(jax.jit, static_argnums=(4, 5, 6))
def _assign_prefiltered(
    cen: Array, t_r: Array, w_r: Array, medoids: Array,
    c: int, rerank_wmd: bool, sink_items: tuple = (),
):
    """WCD-prefilter → candidate-pair RWMD (→ optional Sinkhorn) assignment.

    cen (n, m) doc centroids, t_r (n, h, m) pre-gathered doc embeddings,
    w_r (n, h) weights, medoids (k,).  Returns (labels (n,), dist (n,)).
    """
    d_wcd = dists(cen, cen[medoids])                    # (n, k) cheap
    cand = topk_lib.topk_smallest(d_wcd, c).indices     # (n, c) medoid slots
    med_doc = medoids[cand]                             # (n, c) doc ids
    # One candidate slot at a time: t_r itself is the (n, h, m) left side of
    # every slot, so nothing is ever replicated c-fold.
    cols = []
    for j in range(c):
        sel = med_doc[:, j]
        if rerank_wmd:
            cols.append(wmd_batched_dispatch(
                t_r, w_r, t_r[sel], w_r[sel], **dict(sink_items)))
        else:
            cols.append(rwmd_pairs_from_t(t_r, w_r, t_r[sel], w_r[sel]))
    vals = jnp.stack(cols, axis=1)                      # (n, c)
    best = jnp.argmin(vals, axis=1)
    labels = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
    dist = jnp.take_along_axis(vals, best[:, None], axis=1)[:, 0]
    return labels.astype(jnp.int32), dist


def _resident_t(engine, docs) -> Array:
    """(n*h, m) resident word embeddings for any engine flavor.

    The flat engine pre-gathers this as ``_t_r``; segmented engines keep
    embeddings per segment, so gather from the full table on demand.
    """
    t_r = getattr(engine, "_t_r", None)
    if t_r is None:
        t_r = engine.emb_full[docs.ids.reshape(-1)]
    return t_r


@jax.jit
def _assign_full(d_block: Array):
    """(n, k) engine block → (labels, dist)."""
    return jnp.argmin(d_block, axis=1).astype(jnp.int32), jnp.min(d_block, axis=1)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _medoid_cost_batched(block: Array, labels: Array, k: int, c: int):
    """Per-cluster candidate costs from ONE (n, k·c) engine block.

    ``block[:, j*c + u]`` is the distance of every doc to cluster j's u-th
    shortlisted candidate; membership is masked in-device per cluster, so
    the k per-cluster engine calls of the old medoid-update stage collapse
    into a single batched block.  Returns (k, c) summed member distances.
    """
    n = block.shape[0]
    member = labels[:, None] == jnp.arange(k, dtype=labels.dtype)[None, :]
    blk = block.reshape(n, k, c)
    return jnp.sum(jnp.where(member[:, :, None], blk, 0.0), axis=0)


def kmedoids(
    engine: LCRWMDEngine,
    n_clusters: int,
    *,
    n_iters: int = 8,
    prefilter: int | None = None,
    rerank_wmd: bool = False,
    sinkhorn_kw: dict | None = None,
    medoid_candidates: int = 4,
    init: np.ndarray | None = None,
    seed: int | None = None,
) -> ClusterResult:
    """k-medoids over the engine's resident corpus (see module docstring).

    Returns a :class:`ClusterResult`: ``labels`` (n,) int32 cluster ids,
    ``medoids`` (n_clusters,) int32 doc ids, ``inertia`` float.  All device
    blocks are fixed-shape — (n, n_clusters) assignment blocks and ONE
    (n, n_clusters·medoid_candidates) medoid-update block per iteration —
    so ``n_clusters``/``prefilter``/``medoid_candidates`` are
    compile-relevant: keep them fixed across calls to reuse the engine's
    jit cache.

    ``prefilter``: number of WCD-nearest medoid candidates scored with RWMD
    per doc (None → all ``n_clusters`` scored via one engine block).  A
    speed knob for WCD-friendly corpora ONLY — on centroid-degenerate data
    the prefilter feeds the exact stage garbage (see EXPERIMENTS.md
    §Workloads).
    ``rerank_wmd``: score candidate pairs with batched Sinkhorn-WMD instead
    of the RWMD bound (requires ``prefilter``); ``sinkhorn_kw`` forwards
    solver knobs.
    ``medoid_candidates``: shortlist size for the medoid-update stage.
    ``seed``: explicit PRNG seed forwarded to the :func:`kcenters`
    initializer (ignored when ``init`` is given).  Every downstream stage
    is deterministic given the init, so a fixed seed makes the whole
    clustering reproducible across rebuilds of the same corpus.
    """
    n = engine.resident.n_docs
    if rerank_wmd and prefilter is None:
        prefilter = n_clusters  # WMD rerank rides the candidate-pair path
    if prefilter is not None:
        prefilter = max(1, min(prefilter, n_clusters))
    docs = engine.resident
    n_h = docs.ids.shape[1]
    t_r = _resident_t(engine, docs).reshape(n, n_h, -1)
    cen = centroids_from_t(docs.weights, t_r)  # WCD centroids, gather-free
    sink_items = tuple(sorted((sinkhorn_kw or {}).items()))

    medoids = np.asarray(
        kcenters(engine, n_clusters, seed=seed) if init is None else init,
        dtype=np.int32)
    labels = np.zeros(n, dtype=np.int32)
    obj = float("inf")
    it = 0
    for it in range(1, n_iters + 1):
        med_j = jnp.asarray(medoids)
        if prefilter is None:
            lab, dist = _assign_full(engine.symmetric_resident(med_j))
        else:
            lab, dist = _assign_prefiltered(
                cen, t_r, docs.weights, med_j, prefilter, rerank_wmd,
                sink_items)
        labels = np.asarray(lab)
        obj = float(np.sum(np.asarray(dist)))

        # Medoid update: per cluster, shortlist the members nearest the
        # cluster's WCD centroid, then pick the shortlisted member whose
        # summed RWMD to all members is smallest (exact over the shortlist).
        # ALL clusters' shortlists go through ONE (n, k·c_upd) engine block;
        # per-cluster membership is masked in-device (_medoid_cost_batched)
        # instead of issuing one engine call per cluster.
        new_medoids = medoids.copy()
        cen_np = np.asarray(cen)
        c_upd = medoid_candidates
        shortlists = np.repeat(medoids[:, None], c_upd, axis=1).astype(np.int32)
        valid_len = np.zeros(n_clusters, dtype=np.int64)
        for j in range(n_clusters):
            members = labels == j
            if not members.any():
                continue  # empty cluster keeps its medoid (valid_len 0)
            mean_c = cen_np[members].mean(axis=0)
            m_ids = np.nonzero(members)[0]
            d_c = np.linalg.norm(cen_np[m_ids] - mean_c, axis=1)
            short = m_ids[np.argsort(d_c)[:c_upd]]
            shortlists[j] = np.resize(short, c_upd)  # fixed engine shape
            valid_len[j] = len(short)
        block = engine.symmetric_resident(
            jnp.asarray(shortlists.reshape(-1), jnp.int32))  # (n, k·c_upd)
        costs = np.asarray(_medoid_cost_batched(
            block, jnp.asarray(labels), n_clusters, c_upd))   # (k, c_upd)
        for j in range(n_clusters):
            if valid_len[j]:
                best = int(np.argmin(costs[j, : valid_len[j]]))
                new_medoids[j] = shortlists[j, best]
        if np.array_equal(np.sort(new_medoids), np.sort(medoids)):
            medoids = new_medoids
            break
        medoids = new_medoids
    return ClusterResult(labels=labels, medoids=medoids, objective=obj,
                         n_iters=it)


def kmedoids_wcd_baseline(
    engine: LCRWMDEngine, n_clusters: int, *, n_iters: int = 8,
) -> ClusterResult:
    """WCD-only k-medoids — the cheap baseline the bench compares against.

    Same alternation, but every distance is a centroid distance: no phase-1,
    no swapped direction, no transport.  Paper Fig. 11's point is that WCD
    is a poor WMD proxy; the workloads bench quantifies the clustering gap.
    """
    n = engine.resident.n_docs
    docs = engine.resident
    t_r = _resident_t(engine, docs).reshape(n, docs.ids.shape[1], -1)
    cen = np.asarray(centroids_from_t(docs.weights, t_r))

    # Farthest-first on WCD for seeding (mirrors kcenters).
    medoids = [0]
    mind = np.full(n, np.inf, dtype=np.float32)
    for _ in range(n_clusters - 1):
        mind = np.minimum(
            mind, np.linalg.norm(cen - cen[medoids[-1]], axis=1))
        medoids.append(int(np.argmax(mind)))
    medoids = np.asarray(medoids, dtype=np.int32)

    labels = np.zeros(n, dtype=np.int32)
    obj = float("inf")
    it = 0
    for it in range(1, n_iters + 1):
        d = np.linalg.norm(cen[:, None, :] - cen[medoids][None], axis=2)
        labels = d.argmin(axis=1).astype(np.int32)
        obj = float(d.min(axis=1).sum())
        new_medoids = medoids.copy()
        for j in range(n_clusters):
            m_ids = np.nonzero(labels == j)[0]
            if not len(m_ids):
                continue
            intra = np.linalg.norm(
                cen[m_ids][:, None, :] - cen[m_ids][None], axis=2)
            new_medoids[j] = m_ids[int(intra.sum(axis=1).argmin())]
        if np.array_equal(np.sort(new_medoids), np.sort(medoids)):
            medoids = new_medoids
            break
        medoids = new_medoids
    return ClusterResult(labels=labels, medoids=medoids, objective=obj,
                         n_iters=it)


# ---------------------------------------------------------------------------
# Clustering quality metrics (host-side, label-permutation invariant)
# ---------------------------------------------------------------------------
def purity(pred: np.ndarray, true: np.ndarray) -> float:
    """Fraction of docs in their cluster's majority class."""
    pred = np.asarray(pred)
    true = np.asarray(true)
    total = 0
    for c in np.unique(pred):
        members = true[pred == c]
        total += np.bincount(members).max()
    return float(total / len(true))


def adjusted_rand_index(pred: np.ndarray, true: np.ndarray) -> float:
    """ARI from the pair-counting contingency table (no sklearn)."""
    pred = np.asarray(pred)
    true = np.asarray(true)
    n = len(true)
    cats_p, pred_i = np.unique(pred, return_inverse=True)
    cats_t, true_i = np.unique(true, return_inverse=True)
    table = np.zeros((len(cats_p), len(cats_t)), dtype=np.int64)
    np.add.at(table, (pred_i, true_i), 1)

    def comb2(x):
        return x * (x - 1) / 2.0

    sum_ij = comb2(table).sum()
    sum_a = comb2(table.sum(axis=1)).sum()
    sum_b = comb2(table.sum(axis=0)).sum()
    expected = sum_a * sum_b / comb2(n)
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:
        return 1.0
    return float((sum_ij - expected) / (max_index - expected))
