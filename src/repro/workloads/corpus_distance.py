"""Tiled set-vs-set LC-RWMD: the corpus-analytics scheduler.

The paper motivates LC-RWMD with querying, *clustering*, and classifying
large document sets; the serve engine covers querying only.  This module
turns :class:`~repro.core.lc_rwmd.LCRWMDEngine` into a corpus-vs-corpus
machine without ever materializing the (n, n) distance matrix in HBM.

Self all-pairs (the clustering / dedup substrate)
-------------------------------------------------
The corpus is cut into ``T = ⌈n/tile⌉`` query-side tiles.  Phase 1 runs
ONCE per tile against the engine's restricted vocabulary, fed by the
engine's pre-gathered resident targets (zero embedding-table gathers):
``Z_t = phase1(tile_t)`` of shape (v_e, tile).  The symmetric bound of an
(s, t) block pair is then two CHEAP phase-2 SpMMs::

    D_sym[rows_s, cols_t] = max(phase2(rows_s, Z_t), phase2(rows_t, Z_s)ᵀ)

so only UNORDERED pairs ``s ≤ t`` are visited (the transpose covers the
mirrored block — the symmetry skip halves phase-2 work), the diagonal of
``s == t`` blocks is masked to +inf (self-distance), and each block's
per-row top-k candidates are merged into a RUNNING (tile, k) state per row
tile — the (n, n) matrix never exists; peak intermediates are the
(v_e, n) phase-1 cache (column tiles, O(n·v_e) ≪ O(n²) for n ≫ v_e) and
(tile, tile) distance blocks.

Cross-set (corpus-vs-resident)
------------------------------
An external corpus streams through ``engine.symmetric`` in fixed-size query
tiles: per-query top-k blocks concatenate directly, and the optional
resident-side view keeps a running per-resident top-k merged across tiles.

Total complexity for the self case: O(n·v_e·h·m) phase 1 (linear, the
paper's contribution) + O(n²·h/2) phase 2 — versus O(n²·h²·m) for tiled
quadratic RWMD.
"""

from __future__ import annotations

import functools
import math
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk as topk_lib
from repro.core.lc_rwmd import LCRWMDEngine
from repro.data.docs import DocSet

Array = jax.Array
_INF = jnp.float32(jnp.inf)


class TileBlock(NamedTuple):
    """One symmetric distance block from the self-pair scheduler."""
    s: int           # row-tile index
    t: int           # column-tile index (s <= t)
    row_idx: Array   # (tile,) global doc ids of the block rows
    col_idx: Array   # (tile,) global doc ids of the block columns
    block: Array     # (tile, tile) symmetric LC-RWMD; +inf at diagonal/padding
    mirrored: bool   # True when (col, row) is NOT visited separately (s < t)


def _tile_starts(n: int, tile: int) -> list[int]:
    if tile <= 0:
        raise ValueError(f"tile must be positive, got {tile}")
    return list(range(0, n, tile))


class SelfPairScheduler:
    """Pair-tiled symmetric all-pairs scan over an engine's resident corpus.

    Holds the per-tile phase-1 cache and the jitted block step; consumers
    (top-k, threshold graphs) iterate :meth:`blocks`.
    """

    def __init__(self, engine: LCRWMDEngine, *, tile: int = 64):
        self.engine = engine
        self.n = engine.resident.n_docs
        self.tile = min(tile, self.n)
        self.starts = _tile_starts(self.n, self.tile)
        self._z: list[Array] = []  # phase-1 cache, one (v_e, tile) per tile
        # Segmented engines carry tombstones: snapshot the live mask at
        # construction (the phase-1 cache is a same-version snapshot anyway)
        # and pass it as a TRACED argument so deletes elsewhere never force
        # a re-trace of the block step.  A dead doc's row AND column are
        # masked — it has no neighbors and is no one's neighbor.
        self._live = (engine.live_mask_device()
                      if hasattr(engine, "live_mask_device") else None)
        self._step = jax.jit(self._step_impl)

    def _tile_idx(self, lo: int) -> Array:
        # Global ids; the last tile runs past n and is masked downstream.
        return jnp.arange(lo, lo + self.tile, dtype=jnp.int32)

    def _step_impl(self, z_s: Array, z_t: Array, idx_s: Array, idx_t: Array,
                   live: Array | None = None):
        """max(D1[rows_s, cols_t], D1[rows_t, cols_s]ᵀ), masked."""
        b_st = self.engine._one_sided_rows_impl(idx_s, z_t)  # (tile, tile)
        b_ts = self.engine._one_sided_rows_impl(idx_t, z_s)  # (tile, tile)
        sym = jnp.maximum(b_st, b_ts.T)
        ri, ci = idx_s[:, None], idx_t[None, :]
        invalid = (ri == ci) | (ri >= self.n) | (ci >= self.n)
        if live is not None:
            lr = jnp.take(live, jnp.clip(idx_s, 0, self.n - 1))
            lc = jnp.take(live, jnp.clip(idx_t, 0, self.n - 1))
            invalid = invalid | (~lr[:, None]) | (~lc[None, :])
        return jnp.where(invalid, _INF, sym)

    def _z_tile(self, t: int) -> Array:
        while len(self._z) <= t:
            lo = self.starts[len(self._z)]
            self._z.append(self.engine.phase1_resident(self._tile_idx(lo)))
        return self._z[t]

    def blocks(self) -> Iterator[TileBlock]:
        """Yield every s ≤ t block; s > t is skipped (covered by transpose)."""
        for t, t_lo in enumerate(self.starts):
            z_t = self._z_tile(t)
            idx_t = self._tile_idx(t_lo)
            for s in range(t + 1):
                idx_s = self._tile_idx(self.starts[s])
                yield TileBlock(
                    s=s, t=t, row_idx=idx_s, col_idx=idx_t,
                    block=self._step(self._z[s], z_t, idx_s, idx_t,
                                     self._live),
                    mirrored=s < t,
                )


@functools.partial(jax.jit, static_argnums=(3,))
def _fold_block(carry: topk_lib.TopK, block: Array, col_gids: Array,
                k: int) -> topk_lib.TopK:
    """Fold one (R, C) block row-wise into the shared streaming carry."""
    return topk_lib.StreamingTopK(k).update_rows(carry, block, col_gids)


def corpus_self_topk(
    engine: LCRWMDEngine, k: int, *, tile: int = 64
) -> topk_lib.TopK:
    """Per-document k nearest neighbours over the engine's own corpus.

    Exact symmetric LC-RWMD top-k (self excluded), computed by the pair-tiled
    scheduler — every block folds into the shared
    :class:`~repro.core.topk.StreamingTopK` carry per row tile, so the peak
    distance intermediate is one (tile, tile) block.

    Returns a TopK of (n, k): ascending distances, global doc ids.
    """
    n = engine.resident.n_docs
    n_eff = getattr(engine, "n_live", n)  # tombstones can't be neighbors
    if not 1 <= k <= n_eff - 1:
        raise ValueError(f"need 1 <= k <= n_live-1 = {n_eff - 1}, got {k}")
    sched = SelfPairScheduler(engine, tile=max(tile, k))
    stk = topk_lib.StreamingTopK(k)
    state = [stk.init(sched.tile) for _ in sched.starts]

    for blk in sched.blocks():
        state[blk.s] = _fold_block(state[blk.s], blk.block, blk.col_idx, k)
        if blk.mirrored:
            state[blk.t] = _fold_block(state[blk.t], blk.block.T,
                                       blk.row_idx, k)
    return topk_lib.TopK(
        dists=jnp.concatenate([st.dists for st in state])[:n],
        indices=jnp.concatenate([st.indices for st in state])[:n],
    )


def _pad_docset(ds: DocSet, rows: int) -> DocSet:
    pad = rows - ds.n_docs
    if pad <= 0:
        return ds
    return DocSet(
        ids=jnp.pad(ds.ids, ((0, pad), (0, 0))),
        weights=jnp.pad(ds.weights, ((0, pad), (0, 0))),
    )


class CorpusTopKResult(NamedTuple):
    query_topk: topk_lib.TopK              # (n_corpus, k) over resident docs
    resident_topk: topk_lib.TopK | None    # (n_resident, k) over corpus docs


def corpus_vs_corpus_topk(
    engine: LCRWMDEngine,
    corpus: DocSet,
    k: int,
    *,
    tile: int = 64,
    resident_side: bool = False,
) -> CorpusTopKResult:
    """Per-corpus-doc top-k over the engine's resident set, streamed in tiles.

    Each fixed-size query tile produces one (n_resident, tile) symmetric
    block through the engine (shared query gather, pre-gathered resident
    tensors); per-query top-k rows concatenate directly.  With
    ``resident_side=True`` the same stream also maintains the transposed
    view — per-RESIDENT top-k over the corpus — as a running merge across
    tiles, so neither orientation ever materializes (n_resident, n_corpus).
    """
    n_q = corpus.n_docs
    n_r = engine.resident.n_docs
    k_q = min(k, n_r)       # per-query columns are resident docs
    k_res = min(k, n_q)     # per-resident columns are corpus docs
    tile = min(max(tile, k_res), n_q)
    padded = _pad_docset(corpus, math.ceil(n_q / tile) * tile)
    q_rows: list[topk_lib.TopK] = []
    running = topk_lib.StreamingTopK(k_res).init(n_r) if resident_side else None
    for lo in _tile_starts(n_q, tile):
        d = engine.symmetric(padded.slice_rows(lo, tile))  # (n_r, tile)
        col_gid = jnp.arange(lo, lo + tile, dtype=jnp.int32)
        # Padded query columns hold garbage (0·inf in phase 2); mask by index.
        d = jnp.where((col_gid >= n_q)[None, :], _INF, d)
        q_rows.append(topk_lib.topk_smallest_cols(d, k_q))
        if resident_side:
            running = _fold_block(running, d, col_gid, k_res)
    q_tk = topk_lib.TopK(
        dists=jnp.concatenate([p.dists for p in q_rows])[:n_q],
        indices=jnp.concatenate([p.indices for p in q_rows])[:n_q],
    )
    return CorpusTopKResult(query_topk=q_tk, resident_topk=running)


def corpus_self_topk_distributed(
    engine: LCRWMDEngine,
    mesh,
    k: int,
    *,
    tile: int = 64,
    refine: bool = True,
    rerank_wmd: bool = False,
    wmd_kw: dict | None = None,
    bf16_matmul: bool = False,
) -> topk_lib.TopK:
    """Self-corpus kNN with tiles sharded over a TPU mesh.

    Streams resident tiles as query batches through the engine-backed
    distributed serve step (`distributed/lcrwmd_dist.build_serve_step`) with
    in-mesh self-exclusion: the resident rows stay sharded over the mesh
    batch axes, each tile costs one serve step, and the candidate cascade
    (one-sided top-k → symmetric refine → optional Sinkhorn rerank) matches
    serving semantics — returned distances are exact symmetric RWMD (or WMD)
    for the returned pairs.
    """
    from repro.distributed.lcrwmd_dist import build_serve_step

    n = engine.resident.n_docs
    tile = min(tile, n)
    serve = build_serve_step(
        mesh, k=k, engine=engine, refine=refine, bf16_matmul=bf16_matmul,
        rerank_wmd=rerank_wmd, wmd_kw=wmd_kw, self_exclude=True,
    )
    parts: list[topk_lib.TopK] = []
    for lo in _tile_starts(n, tile):
        idx = jnp.arange(lo, lo + tile, dtype=jnp.int32)
        res = serve(engine.resident_tile(idx), query_ids=idx)
        parts.append(res.topk)
    tk = topk_lib.TopK(
        dists=jnp.concatenate([p.dists for p in parts])[:n],
        indices=jnp.concatenate([p.indices for p in parts])[:n],
    )
    return tk
