"""Near-duplicate graphs from the tiled all-pairs stream.

Consumes the :class:`~repro.workloads.corpus_distance.SelfPairScheduler`
block stream: every symmetric (tile, tile) block is thresholded IN-DEVICE
into a fixed-size survivor list (flat position + distance, compacted with a
shape-static ``nonzero``), so the host only ever touches survivor-sized
arrays — the data-dependent edge count stays host-side while the device
program keeps the scheduler's fixed tile shapes.  Blocks whose survivor
count overflows the fixed capacity (near-duplicate blocks are sparse by
construction, so this is rare) fall back to a full host-side ``np.nonzero``
of that one block.

Graphs are undirected and stored with BOTH orientations (CSR rows are
complete neighbor lists).  ``threshold`` is in symmetric LC-RWMD units —
a LOWER bound on WMD, so a near-duplicate edge here is a superset of the
true WMD near-duplicates at the same threshold (no false dismissals).
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lc_rwmd import LCRWMDEngine
from repro.workloads.corpus_distance import SelfPairScheduler, corpus_self_topk

#: Numeric noise floor of the symmetric LC-RWMD score for EXACT copies.
#: Phase-1 distances come from the matmul form ``||a||² + ||b||² − 2ab``
#: whose cancellation error survives the sqrt, so identical docs score
#: ~7e-4 — NOT 0.  Thresholds below this floor silently miss exact
#: duplicates; :func:`near_duplicate_graph` and :func:`ingest_dedup_mask`
#: clamp up to it (with a warning) instead of failing silently.
DUPLICATE_SCORE_FLOOR: float = 1e-2


def _floor_threshold(threshold: float, caller: str) -> float:
    """Validate/clamp a near-duplicate threshold against the noise floor."""
    if not threshold > 0.0:
        raise ValueError(
            f"{caller}: threshold must be > 0, got {threshold!r}")
    if threshold < DUPLICATE_SCORE_FLOOR:
        warnings.warn(
            f"{caller}: threshold {threshold:g} is below the symmetric "
            f"LC-RWMD numeric noise floor ({DUPLICATE_SCORE_FLOOR:g}); "
            f"exact duplicates score ~7e-4, not 0, so this threshold would "
            f"silently miss them.  Clamping to {DUPLICATE_SCORE_FLOOR:g}.",
            stacklevel=3)
        return DUPLICATE_SCORE_FLOOR
    return threshold


class NeighborGraph(NamedTuple):
    """CSR adjacency over corpus docs (undirected, both orientations)."""
    indptr: np.ndarray    # (n+1,) int64 row pointers
    indices: np.ndarray   # (nnz,) int32 neighbor doc ids
    data: np.ndarray      # (nnz,) f32 symmetric LC-RWMD distances
    n_docs: int

    @property
    def n_edges(self) -> int:
        """Undirected edge count (each stored twice in CSR)."""
        return len(self.indices) // 2

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def _edges_to_csr(rows, cols, vals, n: int) -> NeighborGraph:
    rows = np.concatenate(rows) if rows else np.empty(0, np.int64)
    cols = np.concatenate(cols) if cols else np.empty(0, np.int64)
    vals = np.concatenate(vals) if vals else np.empty(0, np.float32)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=n), out=indptr[1:])
    return NeighborGraph(indptr=indptr, indices=cols.astype(np.int32),
                         data=vals.astype(np.float32), n_docs=n)


@functools.partial(jax.jit, static_argnums=(2,))
def _block_survivors(block: jax.Array, threshold: jax.Array, cap: int):
    """In-device threshold + compaction of one (R, C) block.

    Returns ``(count, flat_pos (cap,), dists (cap,))`` — a fixed-size
    overflow list: positions of the first ``cap`` survivors in flat
    row-major order (shape-static ``nonzero``) plus their distances.  The
    host reads ``count`` and slices; ``count > cap`` signals overflow.
    """
    flat = block.reshape(-1)
    mask = flat <= threshold  # +inf masks never pass
    count = jnp.sum(mask.astype(jnp.int32))
    (pos,) = jnp.nonzero(mask, size=cap, fill_value=0)
    return count, pos.astype(jnp.int32), flat[pos]


def near_duplicate_graph(
    engine: LCRWMDEngine, threshold: float, *, tile: int = 64,
    block_edge_cap: int | None = None,
) -> NeighborGraph:
    """All doc pairs with symmetric LC-RWMD ≤ ``threshold``, as CSR.

    One pass over the s ≤ t tile pairs; mirrored blocks contribute both
    orientations from the same device block (the s == t diagonal block
    already holds both and its self-distance diagonal is pre-masked +inf,
    so identical docs link at distance 0 without self-loops).

    Each block is thresholded and compacted IN-DEVICE to a
    ``block_edge_cap``-sized survivor list (default ``4·tile``), so host
    transfers are survivor-sized, not (tile, tile)-sized; a block whose
    survivor count overflows the cap falls back to a host-side
    ``np.nonzero`` of that one block.
    """
    threshold = _floor_threshold(threshold, "near_duplicate_graph")
    n = engine.resident.n_docs
    sched = SelfPairScheduler(engine, tile=tile)
    cap = block_edge_cap or 4 * sched.tile
    thr = jnp.float32(threshold)
    rows, cols, vals = [], [], []
    for blk in sched.blocks():
        count, pos, d_dev = _block_survivors(blk.block, thr, cap)
        cnt = int(count)
        if cnt == 0:
            continue
        if cnt <= cap:
            flat = np.asarray(pos)[:cnt].astype(np.int64)
            r, c = flat // sched.tile, flat % sched.tile
            d = np.asarray(d_dev)[:cnt]
        else:  # overflow: full host pass for this one (dense) block
            b = np.asarray(blk.block)
            r, c = np.nonzero(b <= threshold)
            d = b[r, c]
        gi = np.asarray(blk.row_idx)[r].astype(np.int64)
        gj = np.asarray(blk.col_idx)[c].astype(np.int64)
        rows.append(gi)
        cols.append(gj)
        vals.append(d)
        if blk.mirrored:  # s < t: the (t, s) block is never visited
            rows.append(gj)
            cols.append(gi)
            vals.append(d)
    return _edges_to_csr(rows, cols, vals, n)


def knn_graph(
    engine: LCRWMDEngine, k: int, *, tile: int = 64, mutual: bool = False
) -> NeighborGraph:
    """k-nearest-neighbor graph from the tiled top-k pass, symmetrized.

    ``mutual=False`` keeps an edge if EITHER endpoint ranks the other in its
    top-k (union symmetrization); ``mutual=True`` requires BOTH (the
    classic near-duplicate criterion — robust to hubness).
    """
    tk = corpus_self_topk(engine, k, tile=tile)
    idx = np.asarray(tk.indices)
    d = np.asarray(tk.dists)
    n = engine.resident.n_docs
    src = np.repeat(np.arange(n, dtype=np.int64), k)
    dst = idx.reshape(-1).astype(np.int64)
    w = d.reshape(-1).astype(np.float32)
    if mutual:
        directed = set(zip(src.tolist(), dst.tolist()))
        keep = np.fromiter(
            ((j, i) in directed for i, j in zip(src, dst)),
            dtype=bool, count=len(src))
        src, dst, w = src[keep], dst[keep], w[keep]
    # Union-symmetrize the surviving arcs, dropping duplicates.
    pair = {}
    for i, j, v in zip(src.tolist(), dst.tolist(), w.tolist()):
        pair[(i, j)] = v
        pair[(j, i)] = v
    if not pair:
        return _edges_to_csr([], [], [], n)
    rows = np.fromiter((p[0] for p in pair), np.int64, len(pair))
    cols = np.fromiter((p[1] for p in pair), np.int64, len(pair))
    vals = np.fromiter(pair.values(), np.float32, len(pair))
    return _edges_to_csr([rows], [cols], [vals], n)


def ingest_dedup_mask(
    engine, docs, threshold: float, *, intra_batch: bool = True,
) -> np.ndarray:
    """(B,) bool gate for ingest: True where a doc is NOT a near-duplicate.

    The serving layer's ingest path calls this before
    :meth:`~repro.core.lc_rwmd.SegmentedEngine.append`: each incoming doc is
    scored by symmetric LC-RWMD against the engine's live corpus (one engine
    call — tombstoned docs are +inf and can't block an ingest), and docs
    within ``threshold`` of an existing doc are dropped.  Because symmetric
    LC-RWMD lower-bounds WMD, every true WMD near-duplicate is caught (no
    false admits); some non-duplicates may be dropped, the usual trade of a
    lower-bound prefilter.

    ``intra_batch=True`` additionally de-dups WITHIN the batch (first
    occurrence wins), so a batch containing its own near-copies admits one.

    Pick ``threshold`` above the numeric noise floor: EXACT copies score
    ~1e-3 (not 0) because phase-1 distances come from the matmul-form
    ``||a||² + ||b||² − 2ab`` whose cancellation error survives the sqrt
    (see the streaming-topk note in tests/test_streaming_topk.py);
    thresholds below :data:`DUPLICATE_SCORE_FLOOR` (1e-2) would silently
    admit exact copies, so they are clamped up to it with a warning.
    """
    threshold = _floor_threshold(threshold, "ingest_dedup_mask")
    b = docs.n_docs
    keep = np.ones(b, dtype=bool)
    if getattr(engine, "n_live", engine.resident.n_docs if engine else 0):
        d = np.asarray(engine.symmetric(docs))        # (n, B); dead rows +inf
        keep &= d.min(axis=0) > threshold
    if intra_batch and b > 1:
        from repro.core.lc_rwmd import lc_rwmd_symmetric

        dd = np.asarray(lc_rwmd_symmetric(docs, docs, engine.emb_full))
        for j in range(1, b):
            if keep[j] and bool((dd[:j, j][keep[:j]] <= threshold).any()):
                keep[j] = False
    return keep


def connected_components(graph: NeighborGraph) -> np.ndarray:
    """(n,) int32 component label per doc — near-duplicate groups."""
    n = graph.n_docs
    parent = np.arange(n)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in range(n):
        for j in graph.indices[graph.indptr[i]:graph.indptr[i + 1]]:
            ri, rj = find(i), find(int(j))
            if ri != rj:
                parent[max(ri, rj)] = min(ri, rj)
    roots = np.fromiter((find(i) for i in range(n)), np.int64, n)
    _, labels = np.unique(roots, return_inverse=True)
    return labels.astype(np.int32)


def duplicate_groups(graph: NeighborGraph) -> list[np.ndarray]:
    """Connected components with ≥ 2 docs, largest first."""
    labels = connected_components(graph)
    groups = [np.nonzero(labels == c)[0]
              for c in np.unique(labels)]
    groups = [g for g in groups if len(g) >= 2]
    return sorted(groups, key=len, reverse=True)
