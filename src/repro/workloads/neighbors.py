"""Near-duplicate graphs from the tiled all-pairs stream.

Consumes the :class:`~repro.workloads.corpus_distance.SelfPairScheduler`
block stream: every symmetric (tile, tile) block is thresholded on the
host and its surviving edges appended to a CSR-style adjacency — the
data-dependent edge count lives entirely host-side, so the device program
keeps the scheduler's fixed tile shapes.

Graphs are undirected and stored with BOTH orientations (CSR rows are
complete neighbor lists).  ``threshold`` is in symmetric LC-RWMD units —
a LOWER bound on WMD, so a near-duplicate edge here is a superset of the
true WMD near-duplicates at the same threshold (no false dismissals).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.lc_rwmd import LCRWMDEngine
from repro.workloads.corpus_distance import SelfPairScheduler, corpus_self_topk


class NeighborGraph(NamedTuple):
    """CSR adjacency over corpus docs (undirected, both orientations)."""
    indptr: np.ndarray    # (n+1,) int64 row pointers
    indices: np.ndarray   # (nnz,) int32 neighbor doc ids
    data: np.ndarray      # (nnz,) f32 symmetric LC-RWMD distances
    n_docs: int

    @property
    def n_edges(self) -> int:
        """Undirected edge count (each stored twice in CSR)."""
        return len(self.indices) // 2

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def _edges_to_csr(rows, cols, vals, n: int) -> NeighborGraph:
    rows = np.concatenate(rows) if rows else np.empty(0, np.int64)
    cols = np.concatenate(cols) if cols else np.empty(0, np.int64)
    vals = np.concatenate(vals) if vals else np.empty(0, np.float32)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=n), out=indptr[1:])
    return NeighborGraph(indptr=indptr, indices=cols.astype(np.int32),
                         data=vals.astype(np.float32), n_docs=n)


def near_duplicate_graph(
    engine: LCRWMDEngine, threshold: float, *, tile: int = 64
) -> NeighborGraph:
    """All doc pairs with symmetric LC-RWMD ≤ ``threshold``, as CSR.

    One pass over the s ≤ t tile pairs; mirrored blocks contribute both
    orientations from the same device block (the s == t diagonal block
    already holds both and its self-distance diagonal is pre-masked +inf,
    so identical docs link at distance 0 without self-loops).
    """
    n = engine.resident.n_docs
    sched = SelfPairScheduler(engine, tile=tile)
    rows, cols, vals = [], [], []
    for blk in sched.blocks():
        b = np.asarray(blk.block)
        r, c = np.nonzero(b <= threshold)  # +inf masks never pass
        if not len(r):
            continue
        gi = np.asarray(blk.row_idx)[r].astype(np.int64)
        gj = np.asarray(blk.col_idx)[c].astype(np.int64)
        d = b[r, c]
        rows.append(gi)
        cols.append(gj)
        vals.append(d)
        if blk.mirrored:  # s < t: the (t, s) block is never visited
            rows.append(gj)
            cols.append(gi)
            vals.append(d)
    return _edges_to_csr(rows, cols, vals, n)


def knn_graph(
    engine: LCRWMDEngine, k: int, *, tile: int = 64, mutual: bool = False
) -> NeighborGraph:
    """k-nearest-neighbor graph from the tiled top-k pass, symmetrized.

    ``mutual=False`` keeps an edge if EITHER endpoint ranks the other in its
    top-k (union symmetrization); ``mutual=True`` requires BOTH (the
    classic near-duplicate criterion — robust to hubness).
    """
    tk = corpus_self_topk(engine, k, tile=tile)
    idx = np.asarray(tk.indices)
    d = np.asarray(tk.dists)
    n = engine.resident.n_docs
    src = np.repeat(np.arange(n, dtype=np.int64), k)
    dst = idx.reshape(-1).astype(np.int64)
    w = d.reshape(-1).astype(np.float32)
    if mutual:
        directed = set(zip(src.tolist(), dst.tolist()))
        keep = np.fromiter(
            ((j, i) in directed for i, j in zip(src, dst)),
            dtype=bool, count=len(src))
        src, dst, w = src[keep], dst[keep], w[keep]
    # Union-symmetrize the surviving arcs, dropping duplicates.
    pair = {}
    for i, j, v in zip(src.tolist(), dst.tolist(), w.tolist()):
        pair[(i, j)] = v
        pair[(j, i)] = v
    if not pair:
        return _edges_to_csr([], [], [], n)
    rows = np.fromiter((p[0] for p in pair), np.int64, len(pair))
    cols = np.fromiter((p[1] for p in pair), np.int64, len(pair))
    vals = np.fromiter(pair.values(), np.float32, len(pair))
    return _edges_to_csr([rows], [cols], [vals], n)


def connected_components(graph: NeighborGraph) -> np.ndarray:
    """(n,) int32 component label per doc — near-duplicate groups."""
    n = graph.n_docs
    parent = np.arange(n)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in range(n):
        for j in graph.indices[graph.indptr[i]:graph.indptr[i + 1]]:
            ri, rj = find(i), find(int(j))
            if ri != rj:
                parent[max(ri, rj)] = min(ri, rj)
    roots = np.fromiter((find(i) for i in range(n)), np.int64, n)
    _, labels = np.unique(roots, return_inverse=True)
    return labels.astype(np.int32)


def duplicate_groups(graph: NeighborGraph) -> list[np.ndarray]:
    """Connected components with ≥ 2 docs, largest first."""
    labels = connected_components(graph)
    groups = [np.nonzero(labels == c)[0]
              for c in np.unique(labels)]
    groups = [g for g in groups if len(g) >= 2]
    return sorted(groups, key=len, reverse=True)
